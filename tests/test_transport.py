"""Cross-process transport subsystem: worker process pools, shard routing,
spill-to-disk fault-in, and backup placement on a different worker."""
import os
import time

import numpy as np
import pytest

from repro.core import (ColmenaQueues, ProcessPoolTaskServer,
                        ShardedValueServer, ValueServer)
from repro.core.transport.shards import HashRing


@pytest.fixture
def proc_queues():
    created = []

    def factory(topics, **kw):
        q = ColmenaQueues(topics, backend="proc", **kw)
        created.append(q)
        return q

    yield factory
    for q in created:
        q.shutdown()


# ---------------------------------------------------------------------------
# process pool: true OS-process workers
# ---------------------------------------------------------------------------

def test_pool_executes_in_worker_processes(proc_queues, tmp_path):
    queues = proc_queues(["t"])
    pool = ProcessPoolTaskServer(queues, workers_per_topic=2)
    sync = str(tmp_path)

    def task():
        # directly-subscribed workers race for tasks, so a fast worker
        # could legitimately drain all six before its sibling finishes
        # starting -- hold each task open until both pids have shown up
        # (bounded), making "both workers participated" deterministic
        pid = os.getpid()
        open(os.path.join(sync, f"{pid}.pid"), "w").close()
        deadline = time.monotonic() + 10
        while (time.monotonic() < deadline
               and len([n for n in os.listdir(sync)
                        if n.endswith(".pid")]) < 2):
            time.sleep(0.01)
        return pid

    pool.register(task, name="t")
    with pool:
        for _ in range(6):
            queues.send_task(method="t", topic="t")
        pids, workers = set(), set()
        for _ in range(6):
            r = queues.get_result("t", timeout=20)
            assert r is not None and r.success
            pids.add(r.value)
            workers.add(r.worker)
    assert os.getpid() not in pids          # genuinely crossed a process
    assert len(pids) == 2                   # both workers participated
    # per-worker identity carries host / topic / rank / pid
    for w in workers:
        assert "/t/w" in w and "/pid" in w


def test_pool_requires_proc_backend():
    queues = ColmenaQueues(["t"])           # local
    with pytest.raises(ValueError):
        ProcessPoolTaskServer(queues)
    queues2 = ColmenaQueues(["t"], backend="proc",
                            value_server=ValueServer(), proxy_threshold=1)
    try:
        with pytest.raises(ValueError):
            ProcessPoolTaskServer(queues2)  # in-process VS can't cross
    finally:
        queues2.shutdown()


def test_pool_error_capture_and_retry(proc_queues):
    queues = proc_queues(["t"])
    pool = ProcessPoolTaskServer(queues, workers_per_topic=1)

    def flaky(x):
        raise RuntimeError("boom")

    pool.register(flaky, name="t", max_retries=2)
    with pool:
        queues.send_task(1, method="t", topic="t")
        r = queues.get_result("t", timeout=20)
    assert r is not None and not r.success
    assert "boom" in r.error
    assert r.task_id is not None


def test_pool_proxies_resolve_across_processes(proc_queues):
    """Sharded VS + proc queues: a worker in another process resolves the
    Thinker's proxied input and proxies its result back."""
    vs = ShardedValueServer(2)
    try:
        queues = proc_queues(["t"], value_server=vs, proxy_threshold=1_000)
        pool = ProcessPoolTaskServer(queues, workers_per_topic=2)
        pool.register(lambda x: x * 2.0, name="t")
        with pool:
            for i in range(8):
                queues.send_task(np.arange(20_000) + i, method="t",
                                 topic="t")
            for _ in range(8):
                r = queues.get_result("t", timeout=30)
                assert r.success
                assert r.value.shape == (20_000,)
        # one-shot inputs and results released after consumption
        assert len(vs) == 0
    finally:
        vs.shutdown()


def test_backup_dispatched_to_different_worker(proc_queues):
    queues = proc_queues(["s"])
    pool = ProcessPoolTaskServer(queues, workers_per_topic=3,
                                 straggler_factor=4.0,
                                 straggler_min_history=5)

    def sim(delay):
        time.sleep(delay)
        return os.getpid()

    pool.register(sim, name="s")
    with pool:
        for _ in range(8):
            queues.send_task(0.02, method="s", topic="s")
        for _ in range(8):
            assert queues.get_result("s", timeout=20) is not None
        tid = queues.send_task(1.5, method="s", topic="s")
        r = queues.get_result("s", timeout=30)
        assert r is not None and r.success
        history = pool.task_history.get(tid, [])
        # the straggler monitor dispatched a backup, and placement put it
        # on a different worker identity than the original
        assert len(history) >= 2, history
        assert len(set(history)) >= 2, history
        # first completion wins; the duplicate is swallowed by the broker
        # claim, never delivered
        assert queues.get_result("s", timeout=2.0) is None
        assert queues.active_count <= 0


# ---------------------------------------------------------------------------
# sharded value server: consistent-hash routing
# ---------------------------------------------------------------------------

def test_hash_ring_routing_is_deterministic_and_spread():
    ring = HashRing(4)
    keys = [f"key-{i}" for i in range(400)]
    nodes = [ring.node(k) for k in keys]
    assert nodes == [ring.node(k) for k in keys]      # deterministic
    counts = [nodes.count(n) for n in range(4)]
    assert all(c > 40 for c in counts), counts        # reasonably spread


def test_hash_ring_consistency_on_grow():
    """Adding a shard moves only a fraction of the key space."""
    r4, r5 = HashRing(4), HashRing(5)
    keys = [f"key-{i}" for i in range(1000)]
    moved = sum(r4.node(k) != r5.node(k) for k in keys)
    assert 0 < moved < 500, moved                     # ~1/5 expected


def test_shard_routing_spreads_keys_and_roundtrips():
    vs = ShardedValueServer(3)
    try:
        keys = {vs.put(np.full(100, i)): i for i in range(30)}
        per_shard = vs.per_shard_stats()
        assert sum(s["puts"] for s in per_shard) == 30
        assert sum(1 for s in per_shard if s["puts"] > 0) >= 2
        for k, i in keys.items():
            assert vs.shard_of(k) == vs.shard_of(k)
            np.testing.assert_array_equal(vs.get(k), np.full(100, i))
        assert len(vs) == 30
        # refcount ops route to the owning shard too
        k0 = vs.put(np.zeros(10), refs=1)
        vs.add_ref(k0)
        assert not vs.release(k0)           # still one reference
        assert vs.release(k0)               # last reference dropped
        assert k0 not in vs
    finally:
        vs.shutdown()


# ---------------------------------------------------------------------------
# spill-to-disk tier
# ---------------------------------------------------------------------------

def test_spill_roundtrip_in_process(tmp_path):
    vs = ValueServer(capacity_bytes=1_000, spill_dir=str(tmp_path))
    a = os.urandom(800)
    b = os.urandom(800)
    ka = vs.put(a)
    kb = vs.put(b)                          # over capacity: a spills
    assert vs.stats["spills"] == 1
    assert ka in vs and kb in vs            # spilled keys still resolvable
    assert vs.total_bytes <= 1_000
    assert vs.spilled_bytes > 0
    assert len(list(tmp_path.iterdir())) == 1
    got = vs.get(ka)                        # fault back in, byte-identical
    assert got == a
    assert vs.stats["spill_hits"] == 1
    assert vs.stats["spills"] == 2          # b spilled to make room
    assert vs.get(kb) == b
    # release of a spilled entry removes its file
    spilled_key = ka if ka not in vs._store else kb
    vs.get(spilled_key)
    victim = next(iter(vs._spilled))
    assert vs.release(victim)
    assert victim not in vs
    assert not (tmp_path / f"{victim}.pkl").exists()


def test_add_ref_on_spilled_key_stays_on_disk(tmp_path):
    """Pinning a spilled entry is a metadata update, not a disk fault-in;
    the refs are restored when a get brings the entry back."""
    vs = ValueServer(capacity_bytes=1_000, spill_dir=str(tmp_path))
    ka = vs.put(os.urandom(800))
    vs.put(os.urandom(800))                 # ka spills
    assert ka not in vs._store and ka in vs
    vs.add_ref(ka)
    vs.add_ref(ka)
    assert ka not in vs._store              # still on disk, no fault-in
    assert not vs.release(ka)               # spilled refs drop without IO
    assert ka not in vs._store
    assert vs.get(ka) is not None           # fault-in restores the pin
    assert vs._store[ka].refs == 1
    assert vs.release(ka)                   # pinned entry deleted at zero


def test_staged_spill_io_does_not_block_resident_gets(tmp_path):
    """The ROADMAP contention fix: a slow spill fault-in holds only its
    key's in-flight marker, not the store lock -- a concurrent get of a
    resident key completes while the disk read is still in flight."""
    import threading

    vs = ValueServer(capacity_bytes=1_000, spill_dir=str(tmp_path))
    spilled = vs.put(os.urandom(800))
    resident = vs.put(os.urandom(400))       # spills `spilled`
    assert spilled not in vs._store

    real_read = vs._read_spill
    in_read = threading.Event()

    def slow_read(key):
        in_read.set()
        time.sleep(0.5)
        return real_read(key)

    vs._read_spill = slow_read
    got = []
    th = threading.Thread(target=lambda: got.append(vs.get(spilled)))
    th.start()
    assert in_read.wait(5), "fault-in never started"
    t0 = time.perf_counter()
    assert vs.get(resident) is not None      # must not queue behind disk
    resident_latency = time.perf_counter() - t0
    th.join()
    assert got and got[0] is not None
    assert resident_latency < 0.25, (
        f"resident get waited {resident_latency:.3f}s behind spill I/O")


def test_staged_spill_same_key_ops_wait_for_marker(tmp_path):
    """Per-key linearizability across the staged window: a get racing an
    in-flight fault-in of the *same* key blocks on the marker and then
    sees the faulted-in value (never a KeyError from the key being in
    neither tier mid-flight)."""
    import threading

    vs = ValueServer(capacity_bytes=1_000, spill_dir=str(tmp_path))
    payload = os.urandom(800)
    key = vs.put(payload)
    vs.put(os.urandom(400))                  # key spills
    real_read = vs._read_spill
    in_read = threading.Event()

    def slow_read(k):
        in_read.set()
        time.sleep(0.3)
        return real_read(k)

    vs._read_spill = slow_read
    results = []
    threads = [threading.Thread(target=lambda: results.append(vs.get(key)))
               for _ in range(3)]
    threads[0].start()
    assert in_read.wait(5)
    for th in threads[1:]:                   # racers arrive mid-fault-in
        th.start()
    for th in threads:
        th.join()
    assert results == [payload] * 3
    assert vs.stats["spill_hits"] == 1       # one disk read served all


def test_staged_spill_concurrent_hammer(tmp_path):
    """Correctness under churn: many threads put/get random keys through
    a tiny capacity bound; every readback is byte-identical and nothing
    is ever lost to a spill/fault race."""
    import threading

    vs = ValueServer(capacity_bytes=4_000, spill_dir=str(tmp_path))
    # unpinned: the working set (7000B) thrashes the 4000B bound, so
    # every hammer round spills and faults concurrently
    blobs = {vs.put(os.urandom(700)): None for _ in range(10)}
    expect = {k: vs.get(k) for k in blobs}
    errors = []

    def hammer(seed):
        rng = np.random.default_rng(seed)
        keys = list(expect)
        for _ in range(60):
            k = keys[rng.integers(len(keys))]
            try:
                if vs.get(k) != expect[k]:
                    errors.append(f"corrupt readback for {k}")
            except Exception as e:           # noqa: BLE001
                errors.append(f"{k}: {e!r}")

    threads = [threading.Thread(target=hammer, args=(i,)) for i in range(6)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert errors == []
    for k in expect:
        assert vs.get(k) == expect[k]


def test_shard_error_frames_keep_connection_alive():
    """A server-side handler exception (e.g. add_ref on a released key)
    comes back as an in-band error, and the same connection keeps
    serving."""
    vs = ShardedValueServer(1)
    try:
        with pytest.raises(RuntimeError, match="vs_add_ref"):
            vs.add_ref("no-such-key")
        key = vs.put(b"still alive")        # same client connection works
        assert vs.get(key) == b"still alive"
    finally:
        vs.shutdown()


def test_spill_never_evicts_pinned(tmp_path):
    vs = ValueServer(capacity_bytes=1_000, spill_dir=str(tmp_path))
    kp = vs.put(os.urandom(800), refs=1)    # pinned
    vs.put(os.urandom(800))
    assert kp in vs._store                  # pinned stayed in memory
    assert vs.stats["spills"] == 0          # the new entry is protected too


def test_spill_roundtrip_over_socket():
    vs = ShardedValueServer(1, capacity_bytes=1_000, spill=True)
    try:
        a = os.urandom(700)
        ka = vs.put(a)
        kb = vs.put(os.urandom(700))
        st = vs.stats
        assert st["spills"] == 1
        assert vs.get(ka) == a              # fault-in through the shard
        assert vs.stats["spill_hits"] == 1
        assert vs.get(kb) is not None
    finally:
        vs.shutdown()


# ---------------------------------------------------------------------------
# batched result drain (multi-consumer Thinker path)
# ---------------------------------------------------------------------------

def test_get_results_batched_drain():
    from repro.core import TaskServer
    queues = ColmenaQueues(["t"])
    server = TaskServer(queues, workers_per_topic=4)
    server.register(lambda x: x, name="t")
    with server:
        for i in range(12):
            queues.send_task(i, method="t", topic="t")
        got = []
        while len(got) < 12:
            batch = queues.get_results("t", max_n=8, timeout=10)
            assert batch, "timed out waiting for results"
            got.extend(r.value for r in batch)
    assert sorted(got) == list(range(12))
    assert queues.active_count == 0


# ---------------------------------------------------------------------------
# durable Value Server: replication, failover, ring rebalancing, snapshots
# ---------------------------------------------------------------------------

def test_hash_ring_successors_distinct_and_stable():
    ring = HashRing([0, 1, 2, 3])
    for i in range(50):
        succ = ring.nodes(f"key-{i}", 3)
        assert len(succ) == len(set(succ)) == 3
        assert succ == ring.nodes(f"key-{i}", 3)      # deterministic
        assert succ[0] == ring.node(f"key-{i}")       # primary first
    # asking for more replicas than shards clamps, never loops
    assert sorted(ring.nodes("x", 99)) == [0, 1, 2, 3]
    # removing a member leaves other keys' primaries untouched
    r3 = HashRing([0, 2, 3])
    for i in range(200):
        if ring.node(f"key-{i}") != 1:
            assert r3.node(f"key-{i}") == ring.node(f"key-{i}")


def test_replicated_get_fails_over_when_primary_killed():
    vs = ShardedValueServer(3, replicas=2)
    try:
        vals = {vs.put(os.urandom(300), sync=True): i for i in range(15)}
        assert len(vs) == 30                # copies counted
        victim = vs.shard_of(next(iter(vals)))
        originals = {k: vs.get(k) for k in vals}
        vs.terminate_shard(victim)
        # every key -- including those whose primary died -- reads back
        # byte-identically from a surviving replica
        for k, v in originals.items():
            assert vs.get(k) == v
        assert vs.client_stats["failovers"] > 0
        assert vs.client_stats["replica_reads"] > 0
    finally:
        vs.shutdown()


def test_replica_refcount_propagation():
    vs = ShardedValueServer(3, replicas=3)
    try:
        key = vs.put(b"pinned" * 100, refs=1, sync=True)
        vs.add_ref(key)
        vs.flush_replication()
        assert not vs.release(key)          # still one reference
        assert vs.release(key)              # last reference dropped
        vs.flush_replication()
        # deleted on EVERY replica, not just the primary
        assert key not in vs
        assert sum(s["len"] for s in vs.per_shard_stats()) == 0
    finally:
        vs.shutdown()


def test_add_shard_migrates_fraction_and_redirects_stale_client():
    vs = ShardedValueServer(3)
    try:
        vals = {vs.put(os.urandom(200)): None for _ in range(60)}
        vals = {k: vs.get(k) for k in vals}
        stale = ShardedValueServer.connect(
            [addr for _, addr in vs._members])
        assert stale._epoch == vs._epoch    # adopted the pushed ring
        new_sid, moved = vs.add_shard()
        # the consistent ring bounds movement to roughly 1/N of the keys
        assert 0 < moved < len(vals) // 2, moved
        for k, v in vals.items():
            assert vs.get(k) == v
        # the stale client is *redirected* -- never served a miss -- and
        # converges on the new ring
        for k, v in vals.items():
            assert stale.get(k) == v
        assert stale._epoch == vs._epoch
        assert stale.client_stats["redirects"] >= 1
        assert any(s["sid"] == new_sid and s["len"] > 0
                   for s in vs.per_shard_stats())
    finally:
        vs.shutdown()


def test_mid_move_get_blocks_until_expected_key_lands():
    """The no-fallback regression (replicas=1 has no replica to absorb a
    transient migration miss): a get for a key the shard was told to
    expect (``vs_expect``) HOLDS its reply until the copy lands, and a
    closed window (``vs_end_expect``) releases held gets to answer the
    miss."""
    import threading
    from repro.core.transport import frames
    vs = ShardedValueServer(2, replicas=1)
    try:
        sid, addr = vs._members[0]
        probe = frames.FrameClient(tuple(addr))
        data = b"migrating" * 50
        vs._send(sid, {"op": "vs_expect", "epoch": 10**6,
                       "keys": ["inflight", "neverlands"]})
        got = []
        th = threading.Thread(target=lambda: got.append(
            probe.request({"op": "vs_get", "key": "inflight"})))
        th.start()
        time.sleep(0.3)
        assert th.is_alive()                # held, not a miss
        vs._send(sid, {"op": "vs_put", "key": "inflight",
                       "size": len(data), "refs": 0}, data)
        th.join(timeout=5)
        assert not th.is_alive()
        h, payload = got[0]
        assert h["ok"] and payload == data
        # a key the migration never delivers answers its miss the moment
        # the window closes -- no 30s stall
        got2 = []
        th2 = threading.Thread(target=lambda: got2.append(
            probe.request({"op": "vs_get", "key": "neverlands"})))
        th2.start()
        time.sleep(0.2)
        assert th2.is_alive()
        vs._send(sid, {"op": "vs_end_expect", "epoch": 10**6})
        th2.join(timeout=5)
        assert not th2.is_alive()
        assert got2[0][0]["ok"] is False
    finally:
        vs.shutdown()


def test_rebalance_mid_move_gets_never_miss_with_single_replica():
    """End-to-end: a slowed migration (replicas=1, so every mid-move key
    has exactly ONE copy) runs concurrently with a client hammering gets
    -- every get returns the right bytes; none sees the pre-expect
    KeyError."""
    import threading
    vs = ShardedValueServer(3, replicas=1)
    orig_transfer = ShardedValueServer._transfer
    try:
        vals = {vs.put(os.urandom(300)): None for _ in range(30)}
        vals = {k: vs.get(k) for k in vals}
        reader = ShardedValueServer.connect([a for _, a in vs._members])

        def slow_transfer(self, *a, **kw):
            time.sleep(0.05)                # widen the mid-move window
            return orig_transfer(self, *a, **kw)

        ShardedValueServer._transfer = slow_transfer
        errs = []

        def hammer():
            try:
                for _ in range(8):
                    for k, v in vals.items():
                        assert reader.get(k) == v
            except Exception as e:          # noqa: BLE001
                errs.append(e)

        th = threading.Thread(target=hammer)
        th.start()
        _, moved = vs.add_shard()
        th.join(timeout=120)
        assert not th.is_alive()
        assert moved > 0
        assert errs == [], errs
    finally:
        ShardedValueServer._transfer = orig_transfer
        vs.shutdown()


def test_remove_shard_drains_its_keys():
    vs = ShardedValueServer(3)
    try:
        vals = {vs.put(os.urandom(200)): None for _ in range(45)}
        vals = {k: vs.get(k) for k in vals}
        victim = vs.shard_of(next(iter(vals)))
        vs.remove_shard(victim)
        assert victim not in [sid for sid, _ in vs._members]
        for k, v in vals.items():
            assert vs.get(k) == v
        assert len(vs) == len(vals)         # nothing lost, nothing doubled
    finally:
        vs.shutdown()


def test_spill_tier_migration_moves_files_by_rename():
    """Co-located shards migrate spilled keys by renaming the spill file
    into the destination's spill dir -- zero payload bytes on the wire."""
    vs = ShardedValueServer(2, capacity_bytes=300, spill=True)
    try:
        vals = {vs.put(os.urandom(250)): None for _ in range(12)}
        vals = {k: vs.get(k) for k in vals}
        assert vs.spilled_bytes > 0         # the capacity bound is biting
        _, moved = vs.add_shard()
        assert moved > 0
        assert vs.client_stats["migrate_renames"] > 0
        for k, v in vals.items():
            assert vs.get(k) == v           # byte-identical after the move
    finally:
        vs.shutdown()


def test_sharded_snapshot_restores_across_topologies():
    """A snapshot taken on one ring restores onto a different shard
    count AND replica factor: restore re-puts through the current ring."""
    vs = ShardedValueServer(3)
    try:
        pinned = vs.put(b"weights" * 50, refs=1)
        vals = {vs.put(os.urandom(200)): None for _ in range(10)}
        vals = {k: vs.get(k) for k in vals}
        blob = vs.snapshot()
        assert vs.snapshot() == blob        # deterministic bytes
    finally:
        vs.shutdown()
    vs2 = ShardedValueServer(2, replicas=2)
    try:
        assert vs2.restore(blob) == len(vals) + 1
        for k, v in vals.items():
            assert vs2.get(k) == v
        assert len(vs2) == (len(vals) + 1) * 2      # replicated on restore
        # refcounts travel: the pinned entry still needs its release
        assert vs2.release(pinned)
        vs2.flush_replication()             # replica delete is async
        assert pinned not in vs2
    finally:
        vs2.shutdown()


def test_value_server_snapshot_roundtrip_includes_spill_tier(tmp_path):
    vs = ValueServer(capacity_bytes=1_000, spill_dir=str(tmp_path / "a"))
    ka = vs.put(os.urandom(600), refs=1)    # pinned: stays in memory
    kb = vs.put(os.urandom(300))
    kc = vs.put(os.urandom(300))            # over capacity: kb spills
    assert vs.spilled_bytes > 0
    blob = vs.snapshot()
    assert vs.snapshot() == blob            # deterministic bytes
    vs2 = ValueServer(spill_dir=str(tmp_path / "b"))
    assert vs2.restore(blob) == 3
    for k in (ka, kb, kc):
        assert vs2.get(k) == vs.get(k)      # both tiers round-trip
    assert vs2._store[ka].refs == 1         # pins survive the round-trip


# ---------------------------------------------------------------------------
# typed array codec: device arrays never pass through pickle
# ---------------------------------------------------------------------------

def test_device_array_roundtrip_never_pickles_array_body(monkeypatch):
    """The acceptance codec test: putting/getting a >= 1 MB jax device
    array through the sharded VS must not hand the array (or its host
    view) to ``pickle.dumps`` -- the body rides as a raw typed buffer.
    Tiny header dicts still pickle; only array-typed arguments are
    banned."""
    import pickle as _pickle
    import jax
    import jax.numpy as jnp

    arr = jnp.arange(1 << 18, dtype=jnp.float32).reshape(512, 512)  # 1 MiB
    real_dumps = _pickle.dumps
    offenders = []

    def guarded(obj, *a, **kw):
        if isinstance(obj, (np.ndarray, jax.Array)):
            offenders.append(type(obj))
        return real_dumps(obj, *a, **kw)

    vs = ShardedValueServer(2, replicas=2)
    try:
        monkeypatch.setattr(_pickle, "dumps", guarded)
        key = vs.put(arr, sync=True)
        out = vs.get(key)
        monkeypatch.undo()
        assert offenders == [], offenders
        assert isinstance(out, jax.Array)
        assert np.array_equal(np.asarray(out), np.asarray(arr))
        # the stored bytes are the typed format, not a pickle stream
        assert vs._get_bytes(key).startswith(b"NDC1")
        # a codec-off client still reads a codec-on writer's value (the
        # formats self-describe) -- and the reverse
        plain = ShardedValueServer.connect(
            [a for _, a in vs._members], array_codec=False)
        assert np.array_equal(np.asarray(plain.get(key)), np.asarray(arr))
        k2 = plain.put(np.asarray(arr))
        assert np.array_equal(np.asarray(vs.get(k2)), np.asarray(arr))
        assert not vs._get_bytes(k2).startswith(b"NDC1")
    finally:
        vs.shutdown()


def test_ndcodec_declines_objects_and_passes_pickles_through():
    from repro.core.transport import ndcodec
    assert ndcodec.encode([1, 2, 3]) is None
    assert ndcodec.encode(np.array([{"a": 1}], dtype=object)) is None
    import pickle as _pickle
    blob = _pickle.dumps({"x": (1, 2)})
    assert ndcodec.decode(blob) == {"x": (1, 2)}
    a = np.arange(12, dtype=np.int64).reshape(3, 4)
    out = ndcodec.decode(ndcodec.encode(a))
    assert np.array_equal(out, a) and out.dtype == a.dtype
    assert ndcodec.nbytes_of(a) == a.nbytes + ndcodec.HEADER_PAD
    assert ndcodec.nbytes_of("not an array") is None


# ---------------------------------------------------------------------------
# shared-memory payload lane: segment lifecycle
# ---------------------------------------------------------------------------

def _shm_available():
    from repro.core.transport import shm
    return shm.shm_dir() is not None


@pytest.mark.skipif(not _shm_available(), reason="no /dev/shm tmpfs")
def test_shm_segment_lifecycle_and_sweep():
    from repro.core.transport import shm
    scope = shm.new_scope()
    data = os.urandom(300_000)
    desc = shm.create_segment(scope, data)
    assert desc is not None and desc["size"] == len(data)
    assert shm.read_segment(desc) == data
    assert shm.live_segments(scope) == [desc["name"]]
    shm.unlink_segment(desc)
    shm.unlink_segment(desc)                # idempotent: no double-free
    assert shm.live_segments(scope) == []
    # a SIGKILLed producer leaks segments no registry saw: the sweep is
    # the teardown backstop that reclaims the whole scope
    descs = [shm.create_segment(scope, b"x" * 1000) for _ in range(3)]
    assert len(shm.live_segments(scope)) == 3
    assert sorted(shm.sweep_scope(scope)) == sorted(d["name"] for d in descs)
    assert shm.live_segments(scope) == []


@pytest.mark.skipif(not _shm_available(), reason="no /dev/shm tmpfs")
def test_shm_segment_fork_safe():
    """A descriptor made before a fork resolves in the child (segments
    are named files, not handles), and the child's exit does not unlink
    what it only read."""
    import multiprocessing
    from repro.core.transport import shm
    ctx = multiprocessing.get_context("fork")
    scope = shm.new_scope()
    data = os.urandom(64_000)
    desc = shm.create_segment(scope, data)

    def child(d, q):
        q.put(shm.read_segment(d) == data)

    q = ctx.SimpleQueue()
    p = ctx.Process(target=child, args=(desc, q))
    p.start()
    assert q.get() is True
    p.join(timeout=5)
    # the parent's copy is untouched by the child's read + exit
    assert shm.read_segment(desc) == data
    shm.unlink_segment(desc)
    assert shm.live_segments(scope) == []


@pytest.mark.skipif(not _shm_available(), reason="no /dev/shm tmpfs")
def test_shm_consumer_killed_between_recv_and_ack_redelivers():
    """A consumer SIGKILLed after resolving a shm-borne payload but
    before acking must not take the segment with it: the broker still
    owns the descriptor, the lease expires, and the redelivery resolves
    the SAME segment -- which is unlinked exactly once, on the final
    ack."""
    import multiprocessing
    import pickle
    import signal as _signal
    from repro.core.transport import shm
    from repro.core.transport.base import Envelope
    from repro.core.transport.proc import ProcTransport
    from repro.utils.timing import now

    ctx = multiprocessing.get_context("fork")
    tr = ProcTransport(lease_timeout=1.0, shm_threshold=1024)
    try:
        scope = tr._owned_scope
        assert scope is not None
        ch = tr.channel("t", "requests")
        payload = os.urandom(200_000)
        ch.put(Envelope(now(), pickle.dumps(payload), {"task_id": "big"}))
        assert len(shm.live_segments(scope)) == 1   # riding shared memory

        def doomed(addr):
            t2 = ProcTransport(address=addr, lease_timeout=1.0)
            c2 = t2.channel("t", "requests")
            envs = c2.get_batch(1)
            assert pickle.loads(envs[0].data) == payload
            os.kill(os.getpid(), _signal.SIGKILL)   # pre-ack: lease dies

        p = ctx.Process(target=doomed, args=(tr.address,))
        p.start()
        p.join(timeout=10)
        # lease expires; the surviving consumer gets the same bytes
        envs = ch.get_batch(1, timeout=10)
        assert envs and pickle.loads(envs[0].data) == payload
        assert envs[0].meta.get("redelivered", 0) >= 1
        ch.ack(flush=True)
        deadline = time.time() + 5
        while shm.live_segments(scope) and time.time() < deadline:
            time.sleep(0.05)
        assert shm.live_segments(scope) == []       # no orphans
    finally:
        tr.close()
