"""Hypothesis property tests on system invariants."""
import pytest

pytest.importorskip("hypothesis")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import ResourceTracker
from repro.core.value_server import Proxy, ValueServer
from repro.kernels.mamba2_ssd import ref as ssd_ref
from repro.models.attention import mha_reference
from repro.optim.compress import dequantize_int8, quantize_int8

SETTINGS = dict(max_examples=20, deadline=None)


# ---------------------------------------------------------------------------
# attention invariants
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    B=st.integers(1, 2), S=st.sampled_from([16, 32, 64]),
    H=st.sampled_from([2, 4]), G=st.sampled_from([1, 2]),
    hd=st.sampled_from([8, 16]),
    chunk=st.sampled_from([8, 16, 64]),
    seed=st.integers(0, 2**16),
)
def test_attention_chunking_invariance(B, S, H, G, hd, chunk, seed):
    """Blockwise online-softmax result is independent of chunk size."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    KVH = H // G
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KVH, hd))
    v = jax.random.normal(ks[2], (B, S, KVH, hd))
    o1 = mha_reference(q, k, v, causal=True, chunk_q=chunk, chunk_k=chunk)
    o2 = mha_reference(q, k, v, causal=True, chunk_q=S, chunk_k=S)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(S=st.sampled_from([16, 32]), seed=st.integers(0, 2**16))
def test_attention_causality(S, seed):
    """Output at position i is unaffected by tokens at positions > i."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    B, H, hd = 1, 2, 8
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    o1 = mha_reference(q, k, v, causal=True, chunk_q=16, chunk_k=16)
    # perturb the future wildly
    k2 = k.at[:, S // 2:].add(100.0)
    v2 = v.at[:, S // 2:].add(-50.0)
    o2 = mha_reference(q, k2, v2, causal=True, chunk_q=16, chunk_k=16)
    np.testing.assert_allclose(np.asarray(o1[:, :S // 2]),
                               np.asarray(o2[:, :S // 2]),
                               rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**16))
def test_attention_softmax_convexity(seed):
    """Each output row is a convex combination of V rows: it lies within
    the per-channel [min, max] envelope of the visible values."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    B, S, H, hd = 1, 32, 2, 8
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    o = np.asarray(mha_reference(q, k, v, causal=False,
                                 chunk_q=16, chunk_k=16))
    vmin = np.asarray(jnp.min(v, axis=1))[:, None]
    vmax = np.asarray(jnp.max(v, axis=1))[:, None]
    assert np.all(o >= vmin - 1e-4) and np.all(o <= vmax + 1e-4)


# ---------------------------------------------------------------------------
# SSD invariants
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(seed=st.integers(0, 2**16), Q=st.sampled_from([8, 16, 32]))
def test_ssd_chunk_invariance(seed, Q):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    B, L, H, P, G, N = 1, 64, 2, 8, 1, 4
    x = jax.random.normal(ks[0], (B, L, H, P))
    la = -jnp.abs(jax.random.normal(ks[1], (B, L, H)))
    b = jax.random.normal(ks[2], (B, L, G, N))
    c = jax.random.normal(ks[3], (B, L, G, N))
    s0 = jax.random.normal(ks[4], (B, H, P, N))
    y1, s1 = ssd_ref.ssd_chunked(x, la, b, c, s0, chunk=Q)
    y2, s2 = ssd_ref.ssd_naive(x, la, b, c, s0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**16))
def test_ssd_linearity_in_x(seed):
    """The SSD scan is linear in x (fixed decay/b/c)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    B, L, H, P, G, N = 1, 32, 2, 4, 1, 4
    x1 = jax.random.normal(ks[0], (B, L, H, P))
    x2 = jax.random.normal(ks[1], (B, L, H, P))
    la = -jnp.abs(jax.random.normal(ks[2], (B, L, H)))
    b = jax.random.normal(ks[3], (B, L, G, N))
    c = jax.random.normal(ks[4], (B, L, G, N))
    y1, _ = ssd_ref.ssd_naive(x1, la, b, c)
    y2, _ = ssd_ref.ssd_naive(x2, la, b, c)
    y12, _ = ssd_ref.ssd_naive(x1 + 2.0 * x2, la, b, c)
    np.testing.assert_allclose(np.asarray(y12), np.asarray(y1 + 2.0 * y2),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# compression invariants
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(seed=st.integers(0, 2**16), scale=st.floats(1e-3, 1e3))
def test_int8_quantization_error_bound(seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(257) * scale, jnp.float32)
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s)
    # error bounded by half a quantization step
    max_err = float(jnp.max(jnp.abs(back - x)))
    assert max_err <= float(s) * 0.5 + 1e-6


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**16))
def test_error_feedback_accumulates_to_truth(seed):
    """With error feedback, the *sum* of dequantized transmissions
    converges to the sum of the true gradients."""
    from repro.optim.compress import compress_tree
    rng = np.random.default_rng(seed)
    true_sum = np.zeros(64, np.float32)
    sent_sum = np.zeros(64, np.float32)
    errors = None
    for _ in range(30):
        g = jnp.asarray(rng.standard_normal(64), jnp.float32) * 0.1
        true_sum += np.asarray(g)
        payload, errors = compress_tree(g, "int8_ef", errors)
        sent_sum += np.asarray(dequantize_int8(*payload))
    resid = np.abs(true_sum - sent_sum)
    # residual equals the current error-feedback buffer -> bounded by one step
    assert np.max(resid) < 0.05


# ---------------------------------------------------------------------------
# core invariants
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    pools=st.dictionaries(st.sampled_from(["a", "b", "c"]),
                          st.integers(0, 16), min_size=2),
    moves=st.lists(st.tuples(st.sampled_from(["a", "b", "c"]),
                             st.sampled_from(["a", "b", "c"]),
                             st.integers(0, 8)), max_size=8),
)
def test_resource_total_conserved(pools, moves):
    rt = ResourceTracker(dict(pools))
    total = sum(pools.values())
    for src, dst, n in moves:
        if src in pools and dst in pools and src != dst:
            rt.reallocate(src, dst, min(n, rt.allocation(src)))
    assert sum(rt.allocation(p) for p in pools) == total


@settings(**SETTINGS)
@given(data=st.binary(min_size=0, max_size=4096))
def test_value_server_roundtrip(data):
    vs = ValueServer()
    key = vs.put(data)
    assert vs.get(key) == data
    p = Proxy(key, len(data))
    assert p.bind(vs).resolve() == data
    # pickled proxies stay tiny regardless of payload
    import pickle
    assert len(pickle.dumps(p)) < 200
