"""Exactly-once dispatch under faults: leased delivery with acks,
redelivery after consumer SIGKILL, broker snapshot/restore, and full
campaign kill-9 -> resume without lost or duplicated completions."""
import os
import signal
import threading
import time

import pytest

from repro.core import (CampaignRecord, ColmenaQueues, Observation,
                        ProcessPoolTaskServer, checkpoint_campaign,
                        resume_campaign)
from repro.core.transport import Envelope, make_transport
from repro.utils.timing import now


@pytest.fixture(params=["local", "proc"])
def make_transport_fixture(request):
    created = []

    def factory(**kw):
        t = make_transport(request.param, **kw)
        created.append(t)
        return t

    factory.backend = request.param
    yield factory
    for t in created:
        t.close()


# ---------------------------------------------------------------------------
# lease semantics (both backends)
# ---------------------------------------------------------------------------

def _get_in_dead_thread(ch, n=1, timeout=2.0):
    """Take a lease on another thread and let the thread die without
    acking -- the minimal model of a killed consumer."""
    got = []
    th = threading.Thread(
        target=lambda: got.extend(ch.get_batch(n, timeout=timeout)))
    th.start()
    th.join()
    return got


def test_unacked_lease_expires_and_redelivers(make_transport_fixture):
    t = make_transport_fixture(lease_timeout=0.4)
    ch = t.channel("t", "requests")
    ch.put(Envelope(now(), b"payload", {"task_id": "a"}))
    got = _get_in_dead_thread(ch)
    assert len(got) == 1
    assert len(ch) == 0                     # leased, not destroyed
    env = ch.get(timeout=3)                 # redelivered after expiry
    assert env is not None and env.data == b"payload"
    assert env.meta["redelivered"] == 1
    ch.ack(flush=True)


def test_acked_lease_is_never_redelivered(make_transport_fixture):
    t = make_transport_fixture(lease_timeout=0.3)
    ch = t.channel("t", "requests")
    ch.put(Envelope(now(), b"x", {}))

    def consume():
        ch.get_batch(1, timeout=2)
        ch.ack(flush=True)

    th = threading.Thread(target=consume)
    th.start()
    th.join()
    assert ch.get(timeout=1.0) is None      # well past the lease timeout


def test_next_get_commits_previous_lease(make_transport_fixture):
    """The poll-is-commit backstop: a drain loop that never calls ack
    keeps its at-least-once semantics without leaking leases."""
    t = make_transport_fixture(lease_timeout=0.3)
    ch = t.channel("t", "requests")
    ch.put(Envelope(now(), b"1", {}))
    ch.put(Envelope(now(), b"2", {}))
    assert ch.get(timeout=1).data == b"1"
    assert ch.get(timeout=1).data == b"2"   # implicitly acks the first
    ch.ack(flush=True)
    assert ch.get(timeout=1.0) is None      # neither ever redelivers


def test_lease_renewal_outlives_timeout(make_transport_fixture):
    """A consumer that legitimately outlives lease_timeout keeps its
    lease via renew -- no redelivery while it heartbeats, normal ack
    afterwards."""
    t = make_transport_fixture(lease_timeout=0.4)
    ch = t.channel("t", "requests")
    ch.put(Envelope(now(), b"long-task", {"task_id": "a"}))
    lease = []
    done = threading.Event()

    def consume():
        got = ch.get_batch(1, timeout=2)
        assert len(got) == 1
        lease.append(ch.held_lease())
        done.wait(3)                         # "executing": holds the lease
        ch.ack(flush=True)

    th = threading.Thread(target=consume)
    th.start()
    deadline = now() + 1.3                   # > 3x the lease timeout
    while now() < deadline:
        time.sleep(0.15)
        if lease:
            # renewed from a *different* thread, by explicit id -- the
            # worker-heartbeat topology
            assert ch.renew(lease[0]) is True
    assert ch.get(timeout=0.2) is None       # never redelivered meanwhile
    done.set()
    th.join()
    assert ch.get(timeout=0.6) is None       # acked: gone for good


def test_renew_after_expiry_reports_too_late(make_transport_fixture):
    t = make_transport_fixture(lease_timeout=0.3)
    ch = t.channel("t", "requests")
    ch.put(Envelope(now(), b"x", {}))
    got = _get_in_dead_thread(ch)
    assert len(got) == 1
    env = ch.get(timeout=3)                  # expiry ran: redelivered
    assert env is not None and env.meta["redelivered"] == 1
    # the original (dead) holder's lease id was 0; renewing it now fails
    assert ch.renew(0) is False
    ch.ack(flush=True)


@pytest.mark.slow
def test_pool_worker_heartbeat_keeps_long_task(tmp_path):
    """End to end: a task 4x longer than lease_timeout runs exactly once
    -- the worker's heartbeat renews the dispatch lease, so the broker
    never redelivers it (before heartbeats, this burned a full duplicate
    execution that only claim-dedup cleaned up)."""
    queues = ColmenaQueues(["t"], backend="proc", lease_timeout=0.5)
    pool = ProcessPoolTaskServer(queues, workers_per_topic=2)

    def long_task(x):
        time.sleep(2.0)
        return (os.getpid(), x)

    pool.register(long_task, name="t")
    try:
        with pool:
            tid = queues.send_task(5, method="t", topic="t")
            r = queues.get_result("t", timeout=30)
            assert r is not None and r.success
            assert r.value[1] == 5
            # exactly one execution: one started event, no redelivery
            assert len(pool.task_history.get(tid, [])) == 1
            assert queues.get_result("t", timeout=1.0) is None
            assert queues.active_count == 0
    finally:
        queues.shutdown()


def test_put_with_claim_publishes_exactly_once(make_transport_fixture):
    t = make_transport_fixture()
    ch = t.channel("t", "results")
    assert ch.put(Envelope(now(), b"winner", {}), claim="tid") is True
    assert ch.put(Envelope(now(), b"loser", {}), claim="tid") is False
    assert len(ch) == 1
    assert ch.get(timeout=1).data == b"winner"
    ch.ack(flush=True)


# ---------------------------------------------------------------------------
# snapshot / restore (both backends)
# ---------------------------------------------------------------------------

def test_snapshot_restore_roundtrip_byte_identical(make_transport_fixture):
    t = make_transport_fixture(lease_timeout=0.5)
    reqs = t.channel("t", "requests")
    results = t.channel("t", "results")
    for i in range(3):
        reqs.put(Envelope(now(), b"task%d" % i, {"task_id": str(i)}))
    results.put(Envelope(now(), b"done", {"output_size": 4}))
    _get_in_dead_thread(reqs)               # one envelope held in-flight
    t.claim("claimed-id")
    snap = t.snapshot()

    t2 = make_transport_fixture(lease_timeout=0.5)
    t2.restore(snap)
    # byte-identical: the snapshot stores lease durations, not deadlines,
    # so identical state must give identical bytes however late we resnap
    assert t2.snapshot() == snap
    # queue depths preserved (the leased envelope is in-flight, not lost)
    assert len(t2.channel("t", "requests")) == 2
    assert len(t2.channel("t", "results")) == 1
    # claim-dedup state preserved
    assert t2.claim("claimed-id") is False
    assert t2.claim("other-id") is True
    # the restored in-flight lease re-arms and redelivers on expiry
    ch2 = t2.channel("t", "requests")
    datas = set()
    while len(datas) < 3:
        env = ch2.get(timeout=3)
        assert env is not None, "restored lease never redelivered"
        datas.add(env.data)
        ch2.ack(flush=True)
    assert datas == {b"task0", b"task1", b"task2"}


def test_checkpoint_resume_preserves_active_count_and_extra(tmp_path):
    queues = ColmenaQueues(["t"])
    for i in range(4):
        queues.send_task(i, method="t", topic="t")
    path = str(tmp_path / "q.ckpt")
    queues.checkpoint(path, extra={"progress": 17})
    fresh = ColmenaQueues(["t"])
    assert fresh.active_count == 0
    extra = fresh.resume(path)
    assert extra == {"progress": 17}
    assert fresh.active_count == 4
    tasks = fresh.get_tasks("t", max_n=10, timeout=1)
    assert [t.args[0] for t in tasks] == [0, 1, 2, 3]


def test_campaign_record_restore_is_atomic():
    """Concurrent readers must observe either the old record or the
    fully restored one -- never the half-restored state the previous
    implementation exposed (clear under the lock, re-add one observation
    at a time outside it)."""
    def make_state(tag, n):
        return [{"entity": f"{tag}{i}", "assay": "a", "prop": "p",
                 "value": float(i), "cost": 1.0, "time": 0.0}
                for i in range(n)]

    rec = CampaignRecord(lambda d: d.get("p"))
    rec.load_state(make_state("old", 300))
    small, big = make_state("new", 200), make_state("old", 300)
    stop = threading.Event()
    partials = []

    def reader():
        while not stop.is_set():
            n = rec.count()
            if n not in (200, 300):     # a mid-restore interleaving
                partials.append(n)

    th = threading.Thread(target=reader)
    th.start()
    try:
        for _ in range(200):
            rec.load_state(small)
            rec.load_state(big)
    finally:
        stop.set()
        th.join()
    assert partials == []


def test_campaign_checkpoint_resume_glue(tmp_path):
    rec = CampaignRecord(lambda d: d.get("ip"))
    for i in range(5):
        rec.add(Observation(f"m{i}", "qc", "ip", float(i), cost=1.0))
    queues = ColmenaQueues(["t"])
    queues.send_task(42, method="t", topic="t")
    path = str(tmp_path / "campaign.ckpt")
    checkpoint_campaign(path, queues, rec, extra={"round": 3})
    q2 = ColmenaQueues(["t"])
    rec2 = CampaignRecord(lambda d: d.get("ip"))
    assert resume_campaign(path, q2, rec2) == {"round": 3}
    assert rec2.value() == 4.0 and rec2.cost() == 5.0
    assert q2.active_count == 1
    assert q2.get_task("t", timeout=1).args[0] == 42


def test_after_result_batch_runs_at_batch_boundary():
    """The blessed checkpoint site: the hook fires only after every
    result of a drained batch has gone through the processor, so a
    checkpoint there can never strand decoded-but-unprocessed results
    (their delivery lease was committed when the batch was decoded)."""
    from repro.core import BaseThinker, TaskServer, result_processor

    class T(BaseThinker):
        def __init__(self, queues):
            super().__init__(queues)
            self.seen = 0
            self.boundaries = []

        @result_processor(topic="t")
        def consumer(self, result):
            self.seen += 1

        def after_result_batch(self, topic):
            # the done/checkpoint decision lives at the batch boundary
            # (mirroring SynThinker's deferred checkpoint)
            self.boundaries.append(self.seen)
            if self.seen >= 10:
                self.done.set()

    queues = ColmenaQueues(["t"])
    server = TaskServer(queues, workers_per_topic=4)
    server.register(lambda x: x, name="t")
    thinker = T(queues)
    with server:
        for i in range(10):
            queues.send_task(i, method="t", topic="t")
        thinker.run(timeout=20)
    assert thinker.seen == 10
    assert thinker.boundaries, "hook never fired"
    # every hook invocation saw a fully-processed prefix, and they are
    # monotonically increasing batch boundaries
    assert thinker.boundaries == sorted(thinker.boundaries)
    assert all(b >= 1 for b in thinker.boundaries)


@pytest.mark.slow
def test_synapp_checkpoint_then_resume(tmp_path):
    """The --checkpoint-every demo end to end, on the backend where the
    guarantee holds end to end: with backend='proc', in-flight work lives
    in broker state (dispatch-queue leases / result queues), so the
    checkpoint captures it and a resumed run finishes the campaign
    without redoing completed tasks."""
    from repro.apps.synapp import SynConfig, run_synapp
    path = str(tmp_path / "syn.ckpt")
    cfg = SynConfig(T=12, D=0.0, I=1 << 10, N=4, use_value_server=False,
                    backend="proc", lease_timeout=1.0,
                    checkpoint_every=5, checkpoint_path=path)
    res = run_synapp(cfg)
    assert res["n_results"] == 12
    assert os.path.exists(path)
    # Checkpoints are deferred to drain-batch boundaries, and the run
    # stops checkpointing once done is set -- so under full-suite load a
    # single batch can carry completions 10..12 and the *last* written
    # checkpoint records completed=5, not 10.  Read the file's actual
    # progress instead of assuming where it landed: the guarantee under
    # test is "resume finishes the campaign and re-runs exactly the
    # not-yet-checkpointed remainder", not "the final checkpoint was at
    # completed=10".
    ckpt = ColmenaQueues.load_checkpoint(path)
    completed_at = ckpt["extra"]["completed"]
    # triggered at a multiple of 5, but *written* at the next batch
    # boundary -- by which point completed may have advanced further
    assert 5 <= completed_at <= 12
    cfg2 = SynConfig(T=12, D=0.0, I=1 << 10, N=4, use_value_server=False,
                     backend="proc", lease_timeout=1.0)
    res2 = run_synapp(cfg2, resume_from=path)
    assert res2["completed_total"] == 12
    # exactly the remainder: completed work is never redone (claims
    # dedup), and nothing checkpointed as done is re-counted
    assert res2["n_results"] == 12 - completed_at


# ---------------------------------------------------------------------------
# chaos: SIGKILL a worker mid-task (proc backend)
# ---------------------------------------------------------------------------

def _pid_of(identity: str) -> int:
    return int(identity.rsplit("/pid", 1)[1])


@pytest.mark.slow
def test_worker_sigkill_redelivers_to_other_worker(tmp_path):
    queues = ColmenaQueues(["t"], backend="proc", lease_timeout=1.0)
    pool = ProcessPoolTaskServer(queues, workers_per_topic=2)

    def slow(x):
        time.sleep(0.6)
        return (os.getpid(), x)

    pool.register(slow, name="t")
    try:
        with pool:
            tid = queues.send_task(7, method="t", topic="t")
            deadline = time.time() + 10
            while not pool.task_history.get(tid) and time.time() < deadline:
                time.sleep(0.01)
            history = pool.task_history.get(tid)
            assert history, "task never started"
            victim = _pid_of(history[0])
            os.kill(victim, signal.SIGKILL)   # mid-task: lease unacked
            r = queues.get_result("t", timeout=30)
            assert r is not None and r.success
            # redelivered to a *different* worker process
            assert r.value == (_pid_of(r.worker), 7)
            assert r.value[0] != victim
            # exactly one completion: no duplicate ever arrives
            assert queues.get_result("t", timeout=1.5) is None
            assert queues.active_count == 0
    finally:
        queues.shutdown()


@pytest.mark.slow
def test_worker_sigkill_with_shm_payload_no_orphan_segments():
    """The direct-path acceptance chaos: a task whose payload rides the
    shared-memory lane survives its worker's SIGKILL -- the broker still
    owns the segment, the lease expires, the redelivery resolves the
    same bytes exactly once, and when the dust settles no segment is
    orphaned."""
    from repro.core.transport import shm
    if shm.shm_dir() is None:
        pytest.skip("no /dev/shm tmpfs")
    queues = ColmenaQueues(["t"], backend="proc", lease_timeout=1.0)
    pool = ProcessPoolTaskServer(queues, workers_per_topic=2)

    def slow_digest(blob):
        time.sleep(0.6)
        return (os.getpid(), len(blob))

    pool.register(slow_digest, name="t")
    try:
        scope = queues.transport._owned_scope
        assert scope is not None
        with pool:
            payload = os.urandom(512 * 1024)    # over SHM_THRESHOLD
            tid = queues.send_task(payload, method="t", topic="t")
            deadline = time.time() + 10
            while not pool.task_history.get(tid) and time.time() < deadline:
                time.sleep(0.01)
            history = pool.task_history.get(tid)
            assert history, "task never started"
            victim = _pid_of(history[0])
            os.kill(victim, signal.SIGKILL)     # mid-task: lease unacked
            r = queues.get_result("t", timeout=30)
            assert r is not None and r.success
            assert r.value == (_pid_of(r.worker), len(payload))
            assert r.value[0] != victim
            # exactly once: no duplicate completion ever arrives
            assert queues.get_result("t", timeout=1.5) is None
            assert queues.active_count == 0
            # every segment (request payload, and the result's if it rode
            # shm) is reclaimed once acks settle -- the victim's death
            # must not leak its in-flight segment
            deadline = time.time() + 10
            while shm.live_segments(scope) and time.time() < deadline:
                time.sleep(0.05)
            assert shm.live_segments(scope) == []
    finally:
        queues.shutdown()


# ---------------------------------------------------------------------------
# chaos: kill -9 the whole campaign after a snapshot, then resume
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_campaign_kill9_resume_exactly_once(tmp_path):
    path = str(tmp_path / "campaign.ckpt")

    def sim(x):
        time.sleep(0.25)
        return x * 10

    q1 = ColmenaQueues(["t"], backend="proc", lease_timeout=1.5)
    pool1 = ProcessPoolTaskServer(q1, workers_per_topic=2)
    pool1.register(sim, name="t")
    pool1.start()
    submitted = [q1.send_task(i, method="t", topic="t") for i in range(10)]
    consumed = {}
    for _ in range(4):
        r = q1.get_result("t", timeout=30)
        assert r is not None and r.success
        consumed[r.task_id] = r.value
    q1.checkpoint(path, extra={"note": "pre-kill"})
    # kill -9 the whole incarnation: every worker, then the broker (no
    # graceful stop -- in-flight state survives only via the checkpoint)
    for p in pool1._procs:
        os.kill(p.pid, signal.SIGKILL)
    os.kill(q1.transport._proc.pid, signal.SIGKILL)
    q1.shutdown()                           # reaps; tolerates the dead broker

    q2 = ColmenaQueues(["t"], backend="proc", lease_timeout=1.5)
    assert q2.resume(path) == {"note": "pre-kill"}
    assert q2.active_count == len(submitted) - len(consumed)
    pool2 = ProcessPoolTaskServer(q2, workers_per_topic=2)
    pool2.register(sim, name="t")
    try:
        recovered = {}
        with pool2:
            for _ in range(len(submitted) - len(consumed)):
                r = q2.get_result("t", timeout=60)
                assert r is not None and r.success, r and r.error
                # never a task we already consumed, never a duplicate
                assert r.task_id not in consumed
                assert r.task_id not in recovered
                recovered[r.task_id] = r.value
            # exactly-once: nothing else ever arrives
            assert q2.get_result("t", timeout=2.0) is None
        # zero lost: every submitted id yielded exactly one result
        assert set(consumed) | set(recovered) == set(submitted)
        assert q2.active_count == 0
        for i, tid in enumerate(submitted):
            assert {**consumed, **recovered}[tid] == i * 10
    finally:
        q2.shutdown()


# ---------------------------------------------------------------------------
# chaos: task preemption (broker-side cancel) racing completion, expiry,
# straggler backups, SIGKILL and checkpoints
# ---------------------------------------------------------------------------

def test_cancel_vs_completion_exactly_one_outcome(make_transport_fixture):
    """The cancel op claims the task id through the same window the
    completion's fused put-claim uses, so whichever lands second loses --
    never two outcomes, never zero."""
    t = make_transport_fixture()
    reqs = t.channel("t", "requests")
    results = t.channel("t", "results")
    # order 1: cancel first -- the late completion is swallowed
    reqs.put(Envelope(now(), b"task", {"task_id": "a"}))
    assert reqs.cancel("a") is True
    assert reqs.cancel("a") is False        # second canceller loses too
    assert results.put(Envelope(now(), b"late", {}), claim="a") is False
    assert len(results) == 0
    assert len(reqs) == 0                   # queued copy destroyed
    # order 2: completion first -- the late cancel reports won=False
    reqs.put(Envelope(now(), b"task", {"task_id": "b"}))
    assert results.put(Envelope(now(), b"done", {}), claim="b") is True
    assert reqs.cancel("b") is False
    assert len(results) == 1
    assert results.get(timeout=1).data == b"done"
    results.ack(flush=True)


def test_cancel_wakes_parked_getter(make_transport_fixture):
    """The PR-7 stop-envelope hazard, cancel edition: a getter parked in
    an idle get_batch re-checks its cancel Event only when something
    nudges the wait.  Setting the Event while the getter is parked does
    nothing by itself -- the broker-side cancel's epoch bump must wake
    it, or it sleeps out the full timeout."""
    t = make_transport_fixture()
    ch = t.channel("t", "requests")
    stop = threading.Event()
    out = []
    th = threading.Thread(
        target=lambda: out.append(ch.get_batch(1, timeout=8.0,
                                               cancel=stop)))
    t0 = time.monotonic()
    th.start()
    time.sleep(0.3)                         # getter is parked by now
    stop.set()                              # nothing re-checks it yet...
    assert ch.cancel("a") is True           # ...until the cancel's wake
    th.join(timeout=4)
    assert not th.is_alive(), "parked getter never woke on cancel"
    assert time.monotonic() - t0 < 6.0      # woke early, not at timeout
    assert out == [[]]


def test_cancelled_stays_cancelled_across_snapshot_restore(
        make_transport_fixture):
    """The cancelled-id window rides the snapshot: a resumed fabric
    still refuses the task's completion, still answers is_cancelled,
    and resnaps byte-identically."""
    t = make_transport_fixture()
    ch = t.channel("t", "requests")
    ch.put(Envelope(now(), b"x", {"task_id": "a"}))
    assert ch.cancel("a") is True
    snap = t.snapshot()
    t2 = make_transport_fixture()
    t2.restore(snap)
    # byte-identical resnap: the cancelled window serializes canonically
    # (checked before touching t2 -- instantiating a channel would add
    # an empty queue entry the original image does not have)
    assert t2.snapshot() == snap
    ch2 = t2.channel("t", "requests")
    assert ch2.is_cancelled("a") is True
    assert len(ch2) == 0                    # stripped copy stays stripped
    # a straggler's completion surfacing after the resume still loses
    assert t2.channel("t", "results").put(
        Envelope(now(), b"ghost", {}), claim="a") is False


def test_cancel_revokes_leased_original_and_backup_clone(
        make_transport_fixture):
    """A straggler race in flight when the cancel lands: the original is
    under lease, its backup clone is queued.  Cancel destroys the queued
    clone AND revokes the lease, so nothing ever redelivers."""
    t = make_transport_fixture(lease_timeout=0.4)
    ch = t.channel("t", "requests")
    ch.put(Envelope(now(), b"orig", {"task_id": "a"}))
    lease = []

    def take():
        got = ch.get_batch(1, timeout=2)
        assert len(got) == 1
        lease.append(ch.held_lease())       # thread-local on proc

    th = threading.Thread(target=take)
    th.start()
    th.join()                               # "slow worker": lease unacked
    assert ch.backup(lease[0], "a", {"exclude_worker": "w0"}) is True
    assert len(ch) == 1                     # clone queued for placement
    assert ch.cancel("a") is True
    assert len(ch) == 0                     # clone destroyed
    # the revoked original lease must NOT expire into a redelivery
    assert ch.get(timeout=1.0) is None


def test_cancel_during_lease_expiry_redelivery(make_transport_fixture):
    """Cancel landing inside the expiry->requeue window: wherever the
    envelope currently lives (still leased or already requeued), the
    cancel destroys it and nothing redelivers afterwards."""
    t = make_transport_fixture(lease_timeout=0.3)
    ch = t.channel("t", "requests")
    ch.put(Envelope(now(), b"x", {"task_id": "a"}))
    got = _get_in_dead_thread(ch)           # lease will lapse unacked
    assert len(got) == 1
    time.sleep(0.45)                        # expiry deadline has passed
    assert ch.cancel("a") is True
    assert ch.get(timeout=0.8) is None      # no ghost redelivery
    assert ch.is_cancelled("a") is True


def test_cancel_with_shm_payload_unlinks_segments():
    """A queued envelope whose payload rides the shared-memory lane is
    cancelled: the broker must unlink the segment it owns -- a revoked
    task that leaks its payload segment would exhaust /dev/shm over a
    long campaign."""
    from repro.core.transport import shm
    if shm.shm_dir() is None:
        pytest.skip("no /dev/shm tmpfs")
    t = make_transport("proc")
    try:
        scope = t._owned_scope
        assert scope is not None
        ch = t.channel("t", "requests")
        ch.put(Envelope(now(), os.urandom(512 * 1024), {"task_id": "a"}))
        assert shm.live_segments(scope), "payload did not ride shm"
        assert ch.cancel("a") is True
        deadline = time.time() + 5
        while shm.live_segments(scope) and time.time() < deadline:
            time.sleep(0.05)
        assert shm.live_segments(scope) == []
    finally:
        t.close()


@pytest.mark.slow
def test_cancel_then_sigkill_worker_no_ghost_completion():
    """SIGKILL the worker in the middle of its own cancellation: the
    cancel already claimed the id and revoked the lease, so neither the
    dying worker nor expiry-redelivery may ever produce a result -- and
    the pool keeps serving fresh work afterwards."""
    queues = ColmenaQueues(["t"], backend="proc", lease_timeout=1.0)
    pool = ProcessPoolTaskServer(queues, workers_per_topic=2)

    def task(x, secs):
        time.sleep(secs)
        return (os.getpid(), x)

    pool.register(task, name="t")
    try:
        with pool:
            tid = queues.send_task(1, 30.0, method="t", topic="t")
            deadline = time.time() + 10
            while not pool.task_history.get(tid) and time.time() < deadline:
                time.sleep(0.01)
            history = pool.task_history.get(tid)
            assert history, "task never started"
            assert queues.cancel(tid, "t") is True
            os.kill(_pid_of(history[0]), signal.SIGKILL)  # mid-cancel
            # zero ghosts: no completion from the victim, none via
            # lease-expiry redelivery (timeout spans 2x lease_timeout)
            assert queues.get_result("t", timeout=2.5) is None
            # capacity intact: a fresh task on the surviving worker(s)
            queues.send_task(2, 0.05, method="t", topic="t")
            r = queues.get_result("t", timeout=30)
            assert r is not None and r.success
            assert r.value[1] == 2
    finally:
        queues.shutdown()


@pytest.mark.slow
def test_cancelled_stays_cancelled_across_checkpoint_resume(tmp_path):
    """Full-fabric version of the snapshot test: cancel a queued task,
    checkpoint, kill the broker, resume into a fresh fabric -- the
    cancelled task must not run, the live one must complete."""
    path = str(tmp_path / "cancel.ckpt")
    q1 = ColmenaQueues(["t"], backend="proc", lease_timeout=1.0)
    try:
        cancelled_tid = q1.send_task(1, method="t", topic="t")
        live_tid = q1.send_task(2, method="t", topic="t")
        assert q1.cancel(cancelled_tid, "t") is True
        q1.checkpoint(path, extra={})
    finally:
        q1.shutdown()

    q2 = ColmenaQueues(["t"], backend="proc", lease_timeout=1.0)
    pool = ProcessPoolTaskServer(q2, workers_per_topic=2)

    def t_fn(x):
        return x * 10

    pool.register(t_fn, name="t")
    try:
        q2.resume(path)
        assert q2.active_count == 1         # the cancel already counted
        with pool:
            r = q2.get_result("t", timeout=30)
            assert r is not None and r.success
            assert r.task_id == live_tid and r.value == 20
            # the cancelled task never runs, never completes
            assert q2.get_result("t", timeout=1.5) is None
            assert q2.active_count == 0
    finally:
        q2.shutdown()


@pytest.mark.slow
def test_synapp_checkpoint_then_resume_with_value_server(tmp_path):
    """The lifted restriction, single-broker: the Value Server stays
    ENABLED while checkpointing -- its snapshot rides the checkpoint, so
    the resumed incarnation's restored task proxies resolve from fresh
    shard processes (with replicas) instead of dangling."""
    from repro.apps.synapp import SynConfig, run_synapp
    path = str(tmp_path / "syn-vs.ckpt")
    cfg = SynConfig(T=12, D=0.0, I=1 << 15, N=4, use_value_server=True,
                    vs_shards=2, vs_replicas=2, backend="proc",
                    lease_timeout=2.0, checkpoint_every=5,
                    checkpoint_path=path)
    res = run_synapp(cfg)
    assert res["n_results"] == 12
    assert os.path.exists(path)
    # the checkpoint bundles the VS: the resumed run re-executes only
    # the in-flight remainder, resolving restored payload proxies.
    # (Checkpoints land at batch boundaries, so a slow machine's drain
    # batching can carry the last checkpoint to completed=11 or 12 --
    # the remainder is 0..2, never the first 10.)
    cfg2 = SynConfig(T=12)
    res2 = run_synapp(cfg2, resume_from=path)
    assert res2["completed_total"] == 12
    assert res2["n_results"] <= 2
