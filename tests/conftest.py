"""Shared fixtures + the opt-in lock-order witness plugin.

``pytest --lock-witness`` installs ``repro.analysis.witness`` for the
whole session: every Lock/RLock/Condition created by ``src/repro`` code
is wrapped, per-thread acquisition chains are recorded, and a cycle
fails the acquiring test immediately.  At session end the observed
acquisition graph is compared against the checked-in known-good order
(``analysis/lock_order.toml``); an edge not declared there fails the
session so new lock-order couplings land as an explicit, reviewed diff.
Forked children (broker, pool workers, shards) inherit the witness and
append their edges to a shared sink file, so edges seen only inside a
worker that exits via ``os._exit`` still count.
"""
import os
import tempfile

import numpy as np
import pytest

LOCK_ORDER_TOML = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "analysis", "lock_order.toml")


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_addoption(parser):
    parser.addoption(
        "--lock-witness", action="store_true", default=False,
        help="instrument src/repro locks and fail on lock-order cycles "
             "or acquisition edges missing from analysis/lock_order.toml")


def pytest_configure(config):
    if not config.getoption("--lock-witness"):
        return
    from repro.analysis import witness as W

    fd, sink = tempfile.mkstemp(prefix="lock-witness-", suffix=".jsonl")
    os.close(fd)
    _, allowed_self = W.load_lock_order(LOCK_ORDER_TOML)
    config._witness = W.install(
        W.Witness(sink=sink, allowed_self_edges=allowed_self))
    config._witness_sink = sink


def pytest_sessionfinish(session, exitstatus):
    config = session.config
    witness = getattr(config, "_witness", None)
    if witness is None:
        return
    from repro.analysis import witness as W

    W.uninstall()
    known_edges, allowed_self = W.load_lock_order(LOCK_ORDER_TOML)
    edges, self_edges = W.read_sink(config._witness_sink)
    os.unlink(config._witness_sink)

    new_edges = {e: s for e, s in edges.items() if e not in known_edges}
    new_self = {n: s for n, s in self_edges.items()
                if n not in allowed_self}
    if not new_edges and not new_self:
        return
    lines = ["lock-order witness: undeclared acquisition edges "
             "(add to analysis/lock_order.toml with review):"]
    for (a, b), site in sorted(new_edges.items()):
        lines.append(f'  "{a} -> {b}"  (first seen at {site})')
    for name, site in sorted(new_self.items()):
        lines.append(f'  self-edge "{name}"  (first seen at {site})')
    report = "\n".join(lines)
    tr = config.pluginmanager.get_plugin("terminalreporter")
    if tr is not None:
        tr.write_line(report, red=True)
    session.exitstatus = 3
