"""Colmena core behaviour: thinker agents, task server dispatch/retry/
straggler mitigation, value server proxies, resource reallocation,
campaign record."""
import threading
import time

import numpy as np
import pytest

from repro.core import (AssaySpec, BaseThinker, CampaignRecord, ColmenaQueues,
                        Observation, Proxy, ResourceTracker, TaskServer,
                        ValueServer, agent, result_processor)


def make_fabric(topics, fn_map, *, workers=2, vs=None, threshold=None,
                **server_kw):
    retries = server_kw.pop("_retries", 1)
    queues = ColmenaQueues(topics, value_server=vs, proxy_threshold=threshold)
    server = TaskServer(queues, workers_per_topic=workers, **server_kw)
    for name, fn in fn_map.items():
        server.register(fn, name=name, topic=name, max_retries=retries)
    return queues, server


def test_listing1_policy():
    """The paper's Listing 1: 10 tasks total, 3 in flight."""
    TOTAL, PAR = 10, 3
    queues = ColmenaQueues(["simulate"])
    server = TaskServer(queues, workers_per_topic=PAR)
    server.register(lambda x: x * 2, name="simulate")

    class T(BaseThinker):
        def __init__(self, q):
            super().__init__(q)
            self.results = []

        @agent
        def planner(self):
            for i in range(PAR):
                self.queues.send_task(float(i), method="simulate",
                                      topic="simulate")

        @result_processor(topic="simulate")
        def consumer(self, result):
            assert result.success, result.error
            self.results.append(result.value)
            if len(self.results) >= TOTAL:
                self.done.set()
            elif len(self.results) + self.queues.active_count - 1 < TOTAL:
                self.queues.send_task(1.0, method="simulate",
                                      topic="simulate")

    t = T(queues)
    with server:
        t.run(timeout=30)
    assert len(t.results) == TOTAL
    assert not t.logger_lines


def test_task_retry_then_success():
    attempts = {"n": 0}

    def flaky(x):
        attempts["n"] += 1
        if attempts["n"] < 3:
            raise RuntimeError("transient")
        return x + 1

    queues = ColmenaQueues(["f"])
    server = TaskServer(queues, workers_per_topic=1)
    server.register(flaky, name="f", max_retries=5)
    with server:
        queues.send_task(41, method="f", topic="f")
        r = queues.get_result("f", timeout=10)
    assert r.success and r.value == 42
    assert attempts["n"] == 3


def test_task_error_captured_not_lost():
    def bad(x):
        raise ValueError("permanent failure")

    queues = ColmenaQueues(["b"])
    server = TaskServer(queues, workers_per_topic=1)
    server.register(bad, name="b", max_retries=1)
    with server:
        queues.send_task(1, method="b", topic="b")
        r = queues.get_result("b", timeout=10)
    assert r is not None and not r.success
    assert "permanent failure" in r.error


def test_straggler_backup_dispatch():
    """A task 10x slower than the trailing median gets a backup; the first
    completion wins and only one result is delivered."""
    calls = {"n": 0}
    lock = threading.Lock()

    def sim(delay):
        with lock:
            calls["n"] += 1
            first_slow = (delay > 0.5 and calls["n"] <= 12)
        time.sleep(delay if not first_slow else 0.05)
        # the *original* dispatch of the slow task sleeps long:
        return delay

    def slow_sim(delay):
        with lock:
            calls["n"] += 1
            is_backup = calls["n"] > 11
        time.sleep(0.02 if is_backup else delay)
        return delay

    queues = ColmenaQueues(["s"])
    server = TaskServer(queues, workers_per_topic=4,
                        straggler_factor=4.0, straggler_min_history=5)
    server.register(slow_sim, name="s")
    with server:
        for _ in range(10):
            queues.send_task(0.02, method="s", topic="s")
        for _ in range(10):
            assert queues.get_result("s", timeout=10) is not None
        # now one straggler: original would take 100x median
        queues.send_task(5.0, method="s", topic="s")
        r = queues.get_result("s", timeout=10)
    assert r is not None and r.success


def test_value_server_proxy_roundtrip():
    vs = ValueServer()
    big = np.arange(200_000, dtype=np.float64)
    queues = ColmenaQueues(["t"], value_server=vs, proxy_threshold=10_000)
    server = TaskServer(queues, workers_per_topic=1)
    server.register(lambda x: float(np.sum(x)), name="t")
    with server:
        queues.send_task(big, method="t", topic="t")
        r = queues.get_result("t", timeout=10)
    assert r.success and r.value == float(np.sum(big))
    assert vs.stats["puts"] >= 1 and vs.stats["gets"] >= 1


def test_proxy_small_values_bypass():
    vs = ValueServer()
    queues = ColmenaQueues(["t"], value_server=vs, proxy_threshold=1 << 20)
    server = TaskServer(queues, workers_per_topic=1)
    server.register(lambda x: x, name="t")
    with server:
        queues.send_task(123, method="t", topic="t")
        r = queues.get_result("t", timeout=10)
    assert r.success and r.value == 123
    assert vs.stats["puts"] == 0


def test_worker_cache_hits():
    """Re-used proxy inputs (e.g. model weights) are fetched once."""
    vs = ValueServer()
    weights = np.ones(100_000)
    key = vs.put(weights)
    queues = ColmenaQueues(["t"], value_server=vs, proxy_threshold=1 << 30)
    server = TaskServer(queues, workers_per_topic=1)
    server.register(lambda w, x: float(w[0] + x), name="t")
    with server:
        for i in range(5):
            queues.send_task(Proxy(key, weights.nbytes), float(i),
                             method="t", topic="t")
        for _ in range(5):
            r = queues.get_result("t", timeout=10)
            assert r.success
    assert vs.stats["gets"] == 1        # 4 cache hits


def test_resource_tracker_reallocation():
    rt = ResourceTracker({"sim": 8, "ml": 2})
    assert rt.acquire("sim", 6, timeout=1)
    # move 4 sim slots to ml: only 2 are free now, 2 deferred
    moved = rt.reallocate("sim", "ml", 4)
    assert moved == 2
    assert rt.allocation("ml") == 4
    rt.release("sim", 6)                 # deferred move completes
    assert rt.allocation("ml") == 6
    assert rt.allocation("sim") == 4
    # totals conserved
    assert rt.allocation("sim") + rt.allocation("ml") == 10


def test_resource_acquire_blocks_until_release():
    rt = ResourceTracker({"p": 1})
    assert rt.acquire("p", 1)
    got = []

    def waiter():
        got.append(rt.acquire("p", 1, timeout=5))

    th = threading.Thread(target=waiter)
    th.start()
    time.sleep(0.05)
    rt.release("p", 1)
    th.join(timeout=5)
    assert got == [True]


def test_campaign_record_value_and_cost():
    rec = CampaignRecord(lambda d: d.get("ip"))
    rec.add(Observation("m1", "qc", "ip", 9.5, cost=6.0))
    rec.add(Observation("m2", "qc", "ip", 11.2, cost=6.0))
    rec.add(Observation("m1", "ml", "ip_pred", 9.1, cost=0.001))
    assert rec.value() == 11.2
    assert abs(rec.cost() - 12.001) < 1e-9
    assert rec.count("qc") == 2


def test_campaign_record_checkpoint_roundtrip(tmp_path):
    rec = CampaignRecord(lambda d: d.get("ip"))
    for i in range(5):
        rec.add(Observation(f"m{i}", "qc", "ip", float(i), cost=1.0))
    path = str(tmp_path / "campaign.json")
    rec.save(path)
    rec2 = CampaignRecord(lambda d: d.get("ip"))
    n = rec2.restore(path)
    assert n == 5
    assert rec2.value() == rec.value() == 4.0
    assert rec2.cost() == 5.0


def test_lifecycle_timers_recorded():
    queues = ColmenaQueues(["t"])
    server = TaskServer(queues, workers_per_topic=1)
    server.register(lambda x: (time.sleep(0.05), x)[1], name="t")
    with server:
        queues.send_task(7, method="t", topic="t")
        r = queues.get_result("t", timeout=10)
    assert r.success
    iv = r.timer.intervals
    assert iv["execute"] >= 0.04
    for key in ("serialize_request", "request_queue_transit",
                "result_queue_transit", "serialize_result"):
        assert key in iv, iv
    assert r.comm_overhead() < iv["execute"]  # overhead small vs work
