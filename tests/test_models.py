"""Per-architecture smoke tests: reduced config, one forward + train step on
CPU, asserting output shapes + finiteness; plus incremental-decoding
consistency (prefill + decode_step == full forward)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config
from repro.launch import steps
from repro.models import api

B, S = 2, 32


def _batch(cfg, key):
    ks = jax.random.split(key, 4)
    batch = {}
    if cfg.family == "vlm":
        batch["embeds"] = jax.random.normal(ks[0], (B, S, cfg.d_model))
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)
    else:
        batch["tokens"] = jax.random.randint(ks[1], (B, S), 0,
                                             cfg.vocab_size)
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(ks[2], (B, S, cfg.d_model))
    batch["labels"] = jax.random.randint(ks[3], (B, S), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, reduced=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, aux = api.forward(params, cfg, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    loss, metrics = api.loss_fn(params, cfg, batch)
    assert np.isfinite(float(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step(arch):
    from repro.configs.base import ShardingConfig, TrainConfig
    cfg = get_config(arch, reduced=True)
    state = steps.init_state(cfg, jax.random.PRNGKey(0))
    # warmup_steps=0: the linear warmup gives lr=0 at step 0, which would
    # (correctly) leave parameters unchanged on the very first step
    fn = steps.make_train_step(cfg, TrainConfig(lr=1e-3, warmup_steps=0,
                                                total_steps=10),
                               ShardingConfig())
    batch = _batch(cfg, jax.random.PRNGKey(1))
    new_state, metrics = jax.jit(fn)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["skipped"]) == 0.0
    assert int(new_state["opt"].step) == 1
    # parameters actually moved
    delta = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        state["params"], new_state["params"])
    assert max(jax.tree.leaves(delta)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_incremental_decode_matches_forward(arch):
    cfg = get_config(arch, reduced=True)
    if cfg.family == "vlm":
        pytest.skip("vlm backbone takes embeds; decode exercised via tokens")
    if cfg.is_moe:
        # capacity-based MoE is sequence-dependent: in a full forward pass
        # tokens compete for expert capacity, while a decoded token is
        # routed alone.  With enough capacity (no drops) the two paths are
        # token-independent and must agree exactly.
        cfg = cfg.replace(capacity_factor=8.0)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                                cfg.vocab_size)
    batch_full = {"tokens": tokens}
    batch_prefix = {"tokens": tokens[:, :S]}
    if cfg.is_encdec:
        frames = jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.d_model))
        batch_full["frames"] = frames
        batch_prefix["frames"] = frames

    logits_full, _ = api.forward(params, cfg, batch_full)
    pf_logits, cache = api.prefill(params, cfg, batch_prefix)

    # prefill's last-position logits == forward at position S-1
    np.testing.assert_allclose(
        np.asarray(pf_logits, np.float32),
        np.asarray(logits_full[:, S - 1], np.float32),
        rtol=0.05, atol=0.05)

    # one decode step == forward at position S
    cache = api.grow_cache(cfg, cache, S + 1)
    dl, _ = api.decode_step(params, cfg, cache, tokens[:, S:S + 1],
                            jnp.asarray(S, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(dl, np.float32),
        np.asarray(logits_full[:, S], np.float32),
        rtol=0.05, atol=0.05)


def test_gemma2_softcap_bounds_logits():
    cfg = get_config("gemma2-2b", reduced=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, _ = api.forward(params, cfg, batch)
    assert float(jnp.max(jnp.abs(logits))) <= cfg.final_logit_softcap + 1e-3


def test_param_count_analytic_close():
    """Analytic param accounting tracks actual trees within 5%."""
    from repro.configs.base import param_count
    from repro.utils.trees import tree_count_params
    for arch in ("internlm2-1.8b", "qwen3-8b", "kimi-k2-1t-a32b",
                 "rwkv6-3b", "seamless-m4t-medium"):
        cfg = get_config(arch, reduced=True)
        actual = tree_count_params(api.abstract_params(cfg))
        predicted = param_count(cfg)
        assert abs(actual - predicted) / actual < 0.05, \
            (arch, actual, predicted)
