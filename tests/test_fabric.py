"""Event-driven dispatch fabric: single-serialization, latency floor,
bounded straggler dedup, and value-server refcount/eviction behaviour.

The serialization / latency / straggler suites run over both transport
backends: ``local`` (in-process Condition deques) and ``proc`` (socket
frames through a broker process) -- the fabric contract is identical."""
import threading
import time

import numpy as np
import pytest

from repro.core import (BaseThinker, ColmenaQueues, TaskServer, ValueServer,
                        agent, event_responder)
from repro.core import message as msg_mod
from repro.core.task_server import _BoundedIdSet
from repro.core.value_server import Proxy
from repro.utils.timing import now


@pytest.fixture(params=["local", "proc"])
def make_queues(request):
    """Factory of ColmenaQueues on each backend; tears down broker procs."""
    created = []

    def factory(topics, **kw):
        q = ColmenaQueues(topics, backend=request.param, **kw)
        created.append(q)
        return q

    factory.backend = request.param
    yield factory
    for q in created:
        q.shutdown()


# ---------------------------------------------------------------------------
# serialization: exactly one pickle per message per queue hop
# ---------------------------------------------------------------------------

def test_single_serialization_per_message(monkeypatch, make_queues):
    calls = {"n": 0}
    real = msg_mod.serialize

    def counting(obj):
        calls["n"] += 1
        return real(obj)

    monkeypatch.setattr(msg_mod, "serialize", counting)
    queues = make_queues(["t"])
    server = TaskServer(queues, workers_per_topic=1)
    server.register(lambda x: x + 1, name="t")
    with server:
        queues.send_task(1, method="t", topic="t")
        r = queues.get_result("t", timeout=10)
    assert r.success and r.value == 2
    # one pickle for the Task hop + one for the Result hop -- no re-pickle
    assert calls["n"] == 2, calls["n"]


def test_sizes_and_timers_survive_single_hop():
    """The receiver still sees serialization time / payload sizes even though
    the message is pickled before those numbers exist."""
    queues = ColmenaQueues(["t"])
    server = TaskServer(queues, workers_per_topic=1)
    server.register(lambda x: x * 3, name="t")
    with server:
        queues.send_task(list(range(1000)), method="t", topic="t")
        r = queues.get_result("t", timeout=10)
    assert r.success
    assert r.input_size > 1000          # pickled list of 1000 ints
    assert r.output_size > 1000
    for key in ("serialize_request", "request_queue_transit",
                "serialize_result", "result_queue_transit"):
        assert key in r.timer.intervals, r.timer.intervals
        assert r.timer.intervals[key] >= 0.0


# ---------------------------------------------------------------------------
# latency: no polling floor on the dispatch / result path
# ---------------------------------------------------------------------------

def test_zero_length_task_latency_below_polling_floor(make_queues):
    """A zero-length task must round-trip well under the old 50 ms poll
    interval (an event-driven fabric does this in ~a millisecond; socket
    frames through the broker add ~a millisecond more, still far below
    any polling floor)."""
    queues = make_queues(["t"])
    server = TaskServer(queues, workers_per_topic=1)
    server.register(lambda: None, name="t")
    lat = []
    with server:
        for _ in range(30):
            t0 = now()
            queues.send_task(method="t", topic="t")
            r = queues.get_result("t", timeout=10)
            lat.append(now() - t0)
            assert r is not None and r.success
    median = sorted(lat)[len(lat) // 2]
    assert median < 0.025, f"median round-trip {median*1e3:.2f} ms"


def test_get_tasks_batched_drain(make_queues):
    queues = make_queues(["t"])
    for i in range(5):
        queues.send_task(i, method="t", topic="t")
    batch = queues.get_tasks("t", max_n=3, timeout=1)
    assert len(batch) == 3
    rest = queues.get_tasks("t", max_n=10, timeout=1)
    assert len(rest) == 2
    assert [t.args[0] for t in batch + rest] == [0, 1, 2, 3, 4]


def test_event_responder_wakes_without_polling():
    fired = threading.Event()

    class T(BaseThinker):
        @agent
        def planner(self):
            self.set_event("go")
            fired.wait(5)
            self.done.set()

        @event_responder(event="go")
        def on_go(self):
            fired.set()

    queues = ColmenaQueues(["t"])
    t0 = now()
    T(queues).run(timeout=10)
    assert fired.is_set()
    assert now() - t0 < 5, "responder never woke; planner waited out"


# ---------------------------------------------------------------------------
# straggler dedup: bounded window, duplicates dropped
# ---------------------------------------------------------------------------

def test_bounded_id_set_caps_memory():
    s = _BoundedIdSet(maxlen=4)
    for i in range(10):
        s.add(i)
    assert len(s) == 4
    assert 9 in s and 6 in s
    assert 0 not in s and 5 not in s


def test_done_ids_only_track_raced_tasks(make_queues):
    """Without straggler races the dedup window stays empty -- ordinary
    campaigns never accumulate completed-task ids."""
    queues = make_queues(["t"])
    server = TaskServer(queues, workers_per_topic=2)
    server.register(lambda x: x, name="t")
    with server:
        for i in range(50):
            queues.send_task(i, method="t", topic="t")
        for _ in range(50):
            assert queues.get_result("t", timeout=10) is not None
        assert len(server._done_ids) == 0
        assert len(server._raced_ids) == 0


def test_straggler_race_delivers_exactly_one_result(make_queues):
    attempt = {"n": 0}
    lock = threading.Lock()

    def sim(delay):
        with lock:
            attempt["n"] += 1
            is_backup = attempt["n"] > 11
        time.sleep(0.02 if is_backup else delay)
        return delay

    queues = make_queues(["s"])
    server = TaskServer(queues, workers_per_topic=4,
                        straggler_factor=4.0, straggler_min_history=5)
    server.register(sim, name="s")
    with server:
        for _ in range(10):
            queues.send_task(0.02, method="s", topic="s")
        for _ in range(10):
            assert queues.get_result("s", timeout=10) is not None
        queues.send_task(2.0, method="s", topic="s")
        r = queues.get_result("s", timeout=10)
        assert r is not None and r.success
        # the losing duplicate must be swallowed, not delivered
        assert queues.get_result("s", timeout=2.5) is None
        assert len(server._done_ids) <= 1
        assert queues.active_count <= 0


# ---------------------------------------------------------------------------
# value server: refcounted deletion + LRU eviction
# ---------------------------------------------------------------------------

def test_value_server_refcount_release_deletes():
    vs = ValueServer()
    key = vs.put(np.ones(10), refs=1)
    assert key in vs
    vs.add_ref(key)
    assert not vs.release(key)          # still one reference
    assert key in vs
    assert vs.release(key)              # last reference dropped
    assert key not in vs
    assert vs.stats["deletes"] == 1
    assert vs.release(key) is False     # idempotent on missing keys


def test_value_server_lru_eviction_respects_pins():
    vs = ValueServer(capacity_bytes=300)
    old = vs.put(b"x", size=100)
    pinned = vs.put(b"y", size=100, refs=1)
    mid = vs.put(b"z", size=100)
    vs.get(old)                         # old becomes most-recently-used
    vs.put(b"w", size=100)              # over capacity: evict LRU unpinned
    assert mid not in vs                # least-recently-used unreferenced
    assert old in vs and pinned in vs
    assert vs.stats["evictions"] == 1
    assert vs.total_bytes <= 300


def test_fabric_releases_one_shot_payloads():
    """Proxied task inputs and result values are deleted once consumed --
    a long campaign no longer accumulates per-task payloads."""
    vs = ValueServer()
    queues = ColmenaQueues(["t"], value_server=vs, proxy_threshold=1_000)
    server = TaskServer(queues, workers_per_topic=2)
    server.register(lambda x: x * 2.0, name="t")
    with server:
        for i in range(20):
            queues.send_task(np.arange(50_000) + i, method="t", topic="t")
        for _ in range(20):
            r = queues.get_result("t", timeout=10)
            assert r.success
    assert vs.stats["puts"] == 40       # 20 inputs + 20 outputs
    assert len(vs) == 0                 # ... all released after consumption
    assert vs.total_bytes == 0


def test_one_shot_payloads_skip_worker_cache():
    """Releasing the store entry must not leave a copy in the per-topic
    worker cache (that would just relocate the campaign memory leak)."""
    vs = ValueServer()
    queues = ColmenaQueues(["t"], value_server=vs, proxy_threshold=1_000)
    server = TaskServer(queues, workers_per_topic=1)
    server.register(lambda x: float(x.sum()), name="t")
    with server:
        for i in range(10):
            queues.send_task(np.arange(10_000) + i, method="t", topic="t")
        for _ in range(10):
            assert queues.get_result("t", timeout=10).success
        assert server._caches["t"] == {}
    assert len(vs) == 0


def test_release_inputs_opt_out_keeps_result_args_resolvable():
    vs = ValueServer()
    queues = ColmenaQueues(["t"], value_server=vs, proxy_threshold=1_000,
                           release_inputs=False)
    server = TaskServer(queues, workers_per_topic=1)
    server.register(lambda x: float(x.sum()), name="t")
    with server:
        big = np.arange(10_000)
        queues.send_task(big, method="t", topic="t")
        r = queues.get_result("t", timeout=10)
    assert r.success
    # the resubmission idiom: the input payload survives completion
    assert np.array_equal(r.args[0].resolve(vs), big)


def test_wait_until_done_survives_spurious_wakeups():
    queues = ColmenaQueues(["t"])
    queues.send_task(1, method="t", topic="t")      # 1 task in flight
    waker = threading.Thread(target=lambda: (time.sleep(0.05),
                                             queues.wake_all()))
    waker.start()
    t0 = now()
    assert queues.wait_until_done(timeout=0.5) is False
    assert now() - t0 >= 0.4, "returned early on an unrelated wake_all()"
    waker.join()


def test_user_owned_proxies_are_not_auto_released():
    """Explicitly `put` values (e.g. shared model weights) survive task
    completion; only fabric-minted one-shot payloads are released."""
    vs = ValueServer()
    weights = np.ones(50_000)
    key = vs.put(weights)
    queues = ColmenaQueues(["t"], value_server=vs, proxy_threshold=1 << 30)
    server = TaskServer(queues, workers_per_topic=1)
    server.register(lambda w, x: float(w[0] + x), name="t")
    with server:
        for i in range(3):
            queues.send_task(Proxy(key, weights.nbytes), float(i),
                             method="t", topic="t")
        for _ in range(3):
            assert queues.get_result("t", timeout=10).success
    assert key in vs
