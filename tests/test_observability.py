"""The tracing + metrics plane: sampling, sink round trips, clock-offset
chains, the Chrome-trace export, the Fig.-5 decomposition acceptance
check, meta namespacing, the bounded RateMeter, the span-name-registry
fabriclint pass, and chaos trace continuity (a SIGKILLed attempt leaves
an evidenced sub-trace; the winning attempt alone completes)."""
import json
import os
import signal
import textwrap
import threading
import time

import pytest

from repro import observability as obs
from repro.core import (ColmenaQueues, ProcessPoolTaskServer, TaskServer,
                        message as msg)
from repro.core.transport import Envelope
from repro.observability import metrics as obs_metrics
from repro.observability import trace as obs_trace
from repro.observability.report import (check_decomposition,
                                        decomposition_table, global_offsets,
                                        read_sinks, summarize_metrics,
                                        to_chrome)
from repro.utils.timing import RateMeter, now


@pytest.fixture
def obs_env(tmp_path, monkeypatch):
    """Point the (per-process, env-configured) tracer singleton at a
    fresh sink dir and reset it afterwards so other tests stay
    untraced."""
    monkeypatch.setenv(obs.ENV_DIR, str(tmp_path))
    monkeypatch.setenv(obs.ENV_SAMPLE, "1.0")
    monkeypatch.delenv(obs.ENV_HOST, raising=False)
    obs_trace._T._pid = -1                  # force a re-read of the env
    obs_metrics.reset()
    yield tmp_path
    obs_trace._T._pid = -1                  # next use re-reads restored env
    obs_metrics.reset()


# ---------------------------------------------------------------------------
# RateMeter: bounded sliding window (the unbounded-events fix)
# ---------------------------------------------------------------------------

def test_rate_meter_window_is_bounded():
    m = RateMeter(window_events=16)
    for i in range(1000):
        m.add_busy(0.001)
    # cumulative totals cover the whole campaign ...
    assert m.count == 1000
    assert m.busy == pytest.approx(1.0)
    # ... but the per-event record is capped at the window
    assert len(m.events) == 16


def test_rate_meter_recent_rate():
    m = RateMeter(window_events=64)
    assert m.recent_rate() == 0.0           # no rate from a single event
    m.add_busy(0.0)
    assert m.recent_rate() == 0.0
    for _ in range(9):
        m.add_busy(0.0)
    # 10 events: rate = 9 / (t_last - t_first), positive and finite
    r = m.recent_rate()
    assert r > 0.0
    m2 = RateMeter(window_events=4)
    for _ in range(100):
        m2.add_busy(0.0)
    # the window's rate only looks at the retained 4 events
    assert m2.recent_rate() > 0.0
    assert len(m2.events) == 4


def test_rate_meter_utilization():
    m = RateMeter()
    m.add_busy(0.5)
    m.add_busy(0.5)
    u = m.utilization(capacity=2.0)
    assert 0.0 < u
    assert m.busy == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# meta namespacing: only meta["timers"] reaches Timer.intervals
# ---------------------------------------------------------------------------

def test_unknown_meta_key_never_lands_in_task_timer():
    """The grafting regression, closed structurally: a new top-level
    meta key (bookkeeping) must never be misrecorded as a lifecycle
    interval -- only the namespaced "timers" sub-dict is grafted."""
    q = ColmenaQueues(["t"])
    try:
        task = msg.Task(topic="t", method="m", args=(1,))
        data = msg.timed_serialize(task, task.timer, "serialize_request")
        env = Envelope(now(), data,
                       {"timers": {"serialize_request": 0.25},
                        "task_id": task.task_id,
                        "some_future_flag": 123,    # bookkeeping, not a timer
                        "redelivered": 2})
        decoded = q._decode_task(env)
        assert "some_future_flag" not in decoded.timer.intervals
        assert "redelivered" not in decoded.timer.intervals
        assert "task_id" not in decoded.timer.intervals
        assert decoded.timer.intervals["serialize_request"] >= 0.25
        # delivery-side trace context rides as attributes, not intervals
        assert decoded.attempt == 2
        assert decoded.trace is False
    finally:
        q.shutdown()


def test_unknown_meta_key_never_lands_in_result_timer():
    q = ColmenaQueues(["t"])
    try:
        q.send_task(0, method="m", topic="t")     # active-count balance
        result = msg.Result(task_id="tid-x", topic="t", method="m",
                            success=True, value=7)
        data = msg.serialize(result)
        env = Envelope(now(), data,
                       {"timers": {"serialize_result": 0.125},
                        "output_size": 4, "rogue": "nope"})
        decoded = q._decode_result(env)
        assert "rogue" not in decoded.timer.intervals
        assert "output_size" not in decoded.timer.intervals
        assert decoded.timer.intervals["serialize_result"] == 0.125
        assert decoded.output_size == 4
    finally:
        q.shutdown()


# ---------------------------------------------------------------------------
# tracer: sampling, addr forms, sink round trip
# ---------------------------------------------------------------------------

def test_sampling_deterministic_and_extremes(obs_env):
    assert obs.enabled()
    assert obs.sample_rate() == 1.0
    assert obs.sampled("any-task-at-rate-one")
    obs_trace._T.sample = 0.0
    assert not obs.sampled("any-task-at-rate-zero")
    obs_trace._T.sample = 0.5
    picks = {f"task-{i}": obs.sampled(f"task-{i}") for i in range(400)}
    assert any(picks.values()) and not all(picks.values())
    # deterministic: every hop hashing the same id gets the same verdict
    for tid, verdict in picks.items():
        assert obs.sampled(tid) == verdict


def test_disabled_tracer_is_inert(tmp_path, monkeypatch):
    monkeypatch.delenv(obs.ENV_DIR, raising=False)
    obs_trace._T._pid = -1
    try:
        assert not obs.enabled()
        assert not obs.sampled("anything")
        # span/instant/emit_timers are no-ops: nothing written anywhere
        obs.span("t", "execute", 0.0, 1.0)
        obs.instant("t", "task_started")
        obs.emit_timers("t", {"execute": 1.0})
        obs.flush_metrics(force=True)
        assert list(tmp_path.iterdir()) == []
    finally:
        obs_trace._T._pid = -1


def test_addr_str_canonical_forms():
    assert obs.addr_str(("unix", "/tmp/b.sock")) == "/tmp/b.sock"
    assert obs.addr_str(("127.0.0.1", 5123)) == "127.0.0.1:5123"
    assert obs.addr_str("/tmp/plain.sock") == "/tmp/plain.sock"
    assert obs.addr_str(b"/tmp/bytes.sock") == "/tmp/bytes.sock"


def test_sink_round_trip(obs_env):
    obs.configure(role="tester", host="hX", addr="brk:1", ref="",
                  offset=0.0)
    obs.span("tid1", "execute", 1.0, 2.0, attempt=1, worker="w0")
    obs.instant("tid1", "task_started", attempt=1)
    obs.emit_timers("tid1", {"execute": 1.0})
    obs.counter("tasks_completed").inc(3)
    obs.gauge("worker_busy_frac").set(0.5)
    obs.observe("infer_queue_delay", 0.002)
    obs.flush_metrics(force=True)
    procs, spans, timers, metrics = read_sinks(obs_env)
    (proc,) = [p for p in procs if p["role"] == "tester"]
    assert proc["host"] == "hX" and proc["addr"] == "brk:1"
    execute = [s for s in spans if s["name"] == "execute"]
    assert len(execute) == 1
    # annotated with the emitting proc's identity for clock alignment
    assert execute[0]["host"] == "hX" and execute[0]["role"] == "tester"
    assert execute[0]["attempt"] == 1
    assert execute[0]["args"]["worker"] == "w0"
    instants = [s for s in spans if s["kind"] == "instant"]
    assert len(instants) == 1 and instants[0]["name"] == "task_started"
    assert timers[0]["intervals"] == {"execute": 1.0}
    summary = summarize_metrics(metrics)
    assert summary["counters"]["tasks_completed"] == 3
    assert summary["gauges"]["worker_busy_frac"] == [0.5]


def test_read_sinks_skips_truncated_final_line(obs_env):
    head = json.dumps({"kind": "proc", "host": "h", "role": "r", "pid": 9,
                       "addr": "", "ref": "", "offset": 0.0, "t": 0.0})
    good = json.dumps({"kind": "span", "trace": "t", "name": "execute",
                       "t0": 0.0, "t1": 1.0})
    # a writer SIGKILLed mid-write leaves exactly one torn final line
    (obs_env / "spans-h-r-9.jsonl").write_text(
        head + "\n" + good + '\n{"kind": "span", "trace": "t2", "na')
    procs, spans, _, _ = read_sinks(obs_env)
    assert len(procs) == 1
    assert [s["trace"] for s in spans] == ["t"]


def test_metrics_registry_snapshot():
    obs_metrics.reset()
    try:
        obs.counter("redeliveries").inc()
        obs.counter("redeliveries").inc(4)
        obs.gauge("queue_depth").set(17)
        obs.observe("batch_occupancy", 0.75)
        obs.observe("batch_occupancy", 0.5)
        snap = obs.metrics_snapshot()
        assert snap["counters"]["redeliveries"] == 5
        assert snap["gauges"]["queue_depth"] == 17.0
        h = snap["histos"]["batch_occupancy"]
        assert h["count"] == 2 and h["sum"] == pytest.approx(1.25)
        assert sum(h["buckets"].values()) == 2
    finally:
        obs_metrics.reset()


# ---------------------------------------------------------------------------
# report: offset chains, Chrome export, decomposition check
# ---------------------------------------------------------------------------

def test_global_offsets_compose_along_ref_chain():
    procs = [
        {"host": "h0", "role": "broker", "pid": 1, "addr": "A",
         "ref": "", "offset": 0.0},                 # coordinator = root
        {"host": "h1", "role": "broker", "pid": 2, "addr": "B",
         "ref": "A", "offset": 1.0},                # member -> coordinator
        {"host": "h1", "role": "worker", "pid": 3, "addr": "",
         "ref": "B", "offset": 0.5},                # worker -> member
    ]
    offs = global_offsets(procs)
    assert offs[("h0", "broker", 1)] == 0.0
    assert offs[("h1", "broker", 2)] == 1.0
    assert offs[("h1", "worker", 3)] == pytest.approx(1.5)


def test_to_chrome_event_structure():
    procs = [{"host": "h0", "role": "worker", "pid": 7, "addr": "",
              "ref": "", "offset": 0.0}]
    spans = [{"kind": "span", "trace": "t1", "name": "execute",
              "t0": 10.0, "t1": 10.5, "host": "h0", "role": "worker",
              "pid": 7, "attempt": 1, "args": {"worker": "w"}},
             {"kind": "instant", "trace": "t1", "name": "task_started",
              "t": 10.0, "host": "h0", "role": "worker", "pid": 7}]
    doc = to_chrome(procs, spans)
    json.dumps(doc)                         # must be valid JSON end to end
    events = doc["traceEvents"]
    meta = [e for e in events if e["ph"] == "M"]
    assert meta and meta[0]["args"]["name"] == "h0/worker/7"
    (x,) = [e for e in events if e.get("ph") == "X"]
    assert x["name"] == "execute"
    assert x["dur"] == pytest.approx(0.5e6)     # microseconds
    assert x["ts"] >= 0.0                       # t_zero-normalized
    assert x["args"]["attempt"] == 1 and x["args"]["worker"] == "w"
    (i,) = [e for e in events if e.get("ph") == "i"]
    assert i["name"] == "task_started"


def test_check_decomposition_pass_and_fail():
    spans = [
        {"kind": "span", "trace": "ok", "name": "execute",
         "t0": 0.0, "t1": 0.050},
        {"kind": "span", "trace": "ok", "name": "serialize_request",
         "t0": 0.0, "t1": 0.010},
        # a non-mirrored span must not count toward the sum
        {"kind": "span", "trace": "ok", "name": "queue_wait",
         "t0": 0.0, "t1": 9.0},
        {"kind": "span", "trace": "drifted", "name": "execute",
         "t0": 0.0, "t1": 0.030},           # timer says 0.050: 40% drift
    ]
    timers = [
        {"kind": "timers", "trace": "ok",
         "intervals": {"execute": 0.050, "serialize_request": 0.010,
                       "proxy_put": 5.0}},  # non-mirrored interval ignored
        {"kind": "timers", "trace": "drifted",
         "intervals": {"execute": 0.050}},
        {"kind": "timers", "trace": "tiny",
         "intervals": {"execute": 0.001}},  # under 10ms: skipped as noise
    ]
    checked, failed, worst = check_decomposition(spans, timers,
                                                 max_drift=0.1)
    assert checked == 2
    assert failed == 1
    assert worst == pytest.approx(0.4)
    checked, failed, _ = check_decomposition(spans, timers, max_drift=0.5)
    assert checked == 2 and failed == 0


def test_decomposition_table_rows():
    spans = [{"kind": "span", "trace": "t", "name": "execute",
              "t0": 0.0, "t1": 0.5},
             {"kind": "span", "trace": "t", "name": "execute",
              "t0": 0.0, "t1": 1.5},
             {"kind": "instant", "trace": "t", "name": "task_started",
              "t": 0.0}]
    rows = decomposition_table(spans)
    assert [r[0] for r in rows] == ["execute"]  # instants excluded
    name, n, med, p90, tot = rows[0]
    assert n == 2 and tot == pytest.approx(2.0)


# ---------------------------------------------------------------------------
# traced campaign end to end (local backend, single process)
# ---------------------------------------------------------------------------

def test_traced_local_campaign_decomposition(obs_env):
    q = ColmenaQueues(["t"], trace=1.0, trace_dir=str(obs_env))
    assert q.trace_dir == str(obs_env)
    server = TaskServer(q, workers_per_topic=2)
    server.register(lambda x: time.sleep(0.02) or x * 2, name="t")
    try:
        with server:
            for i in range(6):
                q.send_task(i, method="t", topic="t")
            got = []
            while len(got) < 6:
                r = q.get_result("t", timeout=20)
                assert r is not None and r.success
                got.append(r)
    finally:
        q.shutdown()
    procs, spans, timers, metrics = read_sinks(obs_env)
    assert len(timers) == 6                 # one Timer record per task
    # every task's span sum agrees with its envelope Timer totals
    checked, failed, worst = check_decomposition(spans, timers, 0.1)
    assert checked == 6, f"only {checked} tasks checkable"
    assert failed == 0, f"worst drift {worst:.1%}"
    by_name = {r[0] for r in decomposition_table(spans)}
    assert {"submit", "serialize_request", "queue_wait",
            "request_queue_transit", "execute", "serialize_result",
            "publish_result", "result_queue_transit",
            "deserialize_result"} <= by_name


def test_trace_off_writes_nothing(tmp_path, monkeypatch):
    monkeypatch.delenv(obs.ENV_DIR, raising=False)
    monkeypatch.delenv(obs.ENV_SAMPLE, raising=False)
    obs_trace._T._pid = -1
    q = ColmenaQueues(["t"])
    try:
        assert q.trace_dir == ""
        server = TaskServer(q, workers_per_topic=2)
        with server:
            q.send_task(1, method="t", topic="t")
            server.register(lambda x: x, name="t")
            q.send_task(2, method="t", topic="t")
            r = q.get_result("t", timeout=20)
            assert r is not None
    finally:
        q.shutdown()
        obs_trace._T._pid = -1
    assert list(tmp_path.iterdir()) == []


# ---------------------------------------------------------------------------
# fabriclint: the span-name-registry pass
# ---------------------------------------------------------------------------

def _lint(tmp_path, source):
    from repro.analysis import fabriclint
    f = tmp_path / "mod_under_lint.py"
    f.write_text(textwrap.dedent(source))
    return fabriclint.run([f], passes=["span-name-registry"])


def test_span_name_registry_declared_names_pass(tmp_path):
    findings = _lint(tmp_path, """\
        from repro import observability as obs

        def f(tid):
            obs.span(tid, "execute", 0.0, 1.0)
            obs.instant(tid, "task_started")
            obs.counter("tasks_completed").inc()
            obs.gauge("queue_depth").set(3)
            obs.observe("infer_queue_delay", 0.01)
    """)
    assert findings == []


def test_span_name_registry_flags_undeclared_name(tmp_path):
    findings = _lint(tmp_path, """\
        from repro import observability as obs

        def f(tid):
            obs.span(tid, "execuet", 0.0, 1.0)
            obs.counter("tasks_compelted").inc()
    """)
    assert len(findings) == 2
    assert all(f.pass_name == "span-name-registry" for f in findings)
    assert "execuet" in findings[0].message
    assert "names.py" in findings[0].message


def test_span_name_registry_flags_dynamic_name(tmp_path):
    findings = _lint(tmp_path, """\
        from repro import observability as obs

        def f(tid, which):
            obs.span(tid, "stage_" + which, 0.0, 1.0)
    """)
    assert len(findings) == 1
    assert "non-literal" in findings[0].message


def test_span_name_registry_ignores_other_receivers(tmp_path):
    # Timer.span and arbitrary .counter attributes are not obs calls
    findings = _lint(tmp_path, """\
        class Timer:
            def span(self, name, a, b):
                pass

        def f(timer, db):
            timer.span("not_a_span_name", "m0", "m1")
            db.counter("whatever").inc()
    """)
    assert findings == []


def test_fabric_instrumentation_is_registry_clean():
    """The live instrumentation in core/** and serving/** must satisfy
    its own lint pass (the satellite's enforcement, self-applied)."""
    from repro.analysis import fabriclint
    findings = fabriclint.run(list(fabriclint.DEFAULT_TARGETS),
                              passes=["span-name-registry"])
    assert findings == [], "\n".join(f.render() for f in findings)


def test_clock_ops_are_registered_idempotent():
    from repro.analysis.idempotent_ops import IDEMPOTENT_OPS
    assert "clock_sync" in IDEMPOTENT_OPS
    assert "stats_scrape" in IDEMPOTENT_OPS


# ---------------------------------------------------------------------------
# live scrape: the stats_scrape broker op
# ---------------------------------------------------------------------------

def test_stats_scrape_reports_depth_and_inflight(obs_env):
    from repro.observability.monitor import scrape_address
    q = ColmenaQueues(["t"], backend="proc", trace=1.0,
                      trace_dir=str(obs_env))
    try:
        for i in range(3):
            q.send_task(i, method="t", topic="t")
        stats = scrape_address(q.transport.address)
        assert stats["queue_depth"]["t/requests"] == 3
        assert stats["inflight_leases"]["t/requests"] == 0
        assert "metrics" in stats and "pid" in stats
        # lease one batch WITHOUT acking: depth drops, inflight rises
        envs = q._topics["t"].requests.get_batch(2, timeout=5)
        assert len(envs) == 2
        stats = scrape_address(q.transport.address)
        assert stats["queue_depth"]["t/requests"] == 1
        assert stats["inflight_leases"]["t/requests"] == 2
        # a full drain-and-ack releases the leases again
        q._topics["t"].requests.ack(flush=True)
        stats = scrape_address(q.transport.address)
        assert stats["inflight_leases"]["t/requests"] == 0
    finally:
        q.shutdown()


def test_clock_sync_roundtrip_small_offset(obs_env):
    q = ColmenaQueues(["t"], backend="proc")
    try:
        offset = obs.calibrate(q.transport.clock_sync)
        # same machine, same CLOCK_MONOTONIC: the offset is bounded by
        # the roundtrip (generous slack for a loaded CI box)
        assert abs(offset) < 0.5
    finally:
        q.shutdown()


# ---------------------------------------------------------------------------
# chaos: trace continuity across SIGKILL (proc backend)
# ---------------------------------------------------------------------------

def _pid_of(identity):
    return int(identity.rsplit("/pid", 1)[1])


@pytest.mark.slow
def test_worker_sigkill_leaves_two_attempt_subtraces(obs_env):
    """Kill a worker mid-execute: the dead attempt's sub-trace ends at
    its ``task_started`` instant (flushed to the O_APPEND sink within
    one flusher period of execute starting), the redelivery runs as
    attempt 1, and exactly one attempt publishes."""
    q = ColmenaQueues(["t"], backend="proc", lease_timeout=1.0,
                      trace=1.0, trace_dir=str(obs_env))
    pool = ProcessPoolTaskServer(q, workers_per_topic=2)

    def slow(x):
        time.sleep(0.6)
        return (os.getpid(), x)

    pool.register(slow, name="t")
    try:
        with pool:
            tid = q.send_task(7, method="t", topic="t")
            deadline = time.time() + 10
            while not pool.task_history.get(tid) and time.time() < deadline:
                time.sleep(0.01)
            history = pool.task_history.get(tid)
            assert history, "task never started"
            victim = _pid_of(history[0])
            # the contract: crash evidence survives for any execution
            # longer than one flush period.  Give the victim's flusher
            # two periods to land task_started, then kill mid-execute
            # (the task sleeps 0.6s) with the lease still unacked.
            time.sleep(2.5 * obs_trace.FLUSH_SECONDS)
            os.kill(victim, signal.SIGKILL)
            r = q.get_result("t", timeout=30)
            assert r is not None and r.success
            assert r.value[0] != victim
            assert q.get_result("t", timeout=1.5) is None
    finally:
        q.shutdown()
    _, spans, timers, _ = read_sinks(obs_env)
    mine = [s for s in spans if s.get("trace") == tid]
    assert mine, "no spans for the traced task"
    started = [s for s in mine if s["name"] == "task_started"]
    attempts = {s.get("attempt", 0) for s in started}
    # one sub-trace per delivery attempt: the killed original (0) and
    # the lease-expiry redelivery (1).  A loaded box may expire the
    # lease again mid-retry and add further attempts; 0 and 1 are the
    # guaranteed floor.
    assert {0, 1} <= attempts, f"attempt instants: {sorted(attempts)}"
    # the SIGKILLed attempt 0 never closed its execute span: every
    # completing execute belongs to a redelivery
    execs = [s for s in mine
             if s["name"] == "execute" and s["kind"] == "span"]
    assert execs and all(e.get("attempt", 0) >= 1 for e in execs)
    # exactly one publish won the first-completion claim fabric-wide
    pubs = [s for s in mine if s["name"] == "publish_result"]
    claimed = [p for p in pubs if (p.get("args") or {}).get("claimed")]
    assert len(claimed) == 1, f"{len(claimed)} claimed of {len(pubs)}"
    # the consumer-side Timer record exists for the winning attempt
    assert [t for t in timers if t["trace"] == tid]


@pytest.mark.slow
def test_shard_sigkill_leaves_no_orphan_traces(obs_env):
    """Kill an inference shard mid-campaign with tracing on: every
    sampled request's trace still reaches a result (redelivery to the
    replacement shard), so no trace dangles without completion spans."""
    from repro.serving.shard import (InferenceClient, ServeSpec,
                                     start_inference_shard)
    from tests.test_serving_shard import _slow_stub_factory

    spec = ServeSpec(engine_factory=_slow_stub_factory, max_batch=4,
                     prompt_buckets=(8,), max_batch_delay_ms=5.0)
    q = ColmenaQueues([], backend="proc", lease_timeout=1.0,
                      serve_spec=spec, trace=1.0, trace_dir=str(obs_env))
    procs = []
    try:
        procs.append(start_inference_shard(
            q.transport.address, spec, lease_timeout=1.0,
            identity="infer@chaos:0"))
        client = InferenceClient(q)
        tids = client.submit([[i + 1, i + 2] for i in range(12)],
                             max_new=6)
        got: dict = {}
        deadline = time.time() + 30
        while not got and time.time() < deadline:
            for r in q.get_results(spec.topic, max_n=64, timeout=0.5):
                got.setdefault(r.task_id, []).append(r)
        assert got, "shard produced nothing before the kill"
        assert len(got) < 12, "campaign finished before the kill"
        os.kill(procs[0].pid, signal.SIGKILL)
        procs[0].join(timeout=5)
        procs.append(start_inference_shard(
            q.transport.address, spec, lease_timeout=1.0,
            identity="infer@chaos:1"))
        deadline = time.time() + 60
        while len(got) < 12 and time.time() < deadline:
            for r in q.get_results(spec.topic, max_n=64, timeout=0.5):
                got.setdefault(r.task_id, []).append(r)
        assert sorted(got) == sorted(tids)
        assert not {t: len(rs) for t, rs in got.items() if len(rs) > 1}
    finally:
        for p in procs:
            if p.is_alive():
                p.terminate()
                p.join(timeout=3)
        q.shutdown()
    _, spans, _, _ = read_sinks(obs_env)
    by_trace: dict = {}
    for s in spans:
        by_trace.setdefault(s.get("trace"), []).append(s)
    # every request trace completed: a result_queue_transit span exists
    # (the thinker decoded exactly one result per id), so nothing is
    # orphaned at the dead shard's in-flight point
    for tid in tids:
        names = {s["name"] for s in by_trace.get(tid, [])}
        assert "result_queue_transit" in names, (
            f"trace {tid} dangles with only {sorted(names)}")
        # exactly one claimed retirement fabric-wide per id
        claimed = [s for s in by_trace[tid]
                   if s["name"] == "retire"
                   and (s.get("args") or {}).get("claimed")]
        assert len(claimed) <= 1
    # no spans for ids the campaign never issued (stop markers etc. are
    # untraced control traffic)
    assert set(by_trace) <= set(tids)
