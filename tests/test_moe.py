"""MoE dispatch semantics: implementation equivalence + capacity behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import api, moe


def _setup(cf=8.0, dtype="float32"):
    cfg = get_config("kimi-k2-1t-a32b", reduced=True).replace(
        capacity_factor=cf, compute_dtype=dtype, param_dtype=dtype)
    params = api.init_params(cfg, jax.random.PRNGKey(1))
    p = jax.tree.map(lambda t: t[0], params["stack"]["uniform"]["ffn"])
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model),
                          jnp.float32)
    return cfg, p, x


def test_dropping_equals_einsum_oracle():
    cfg, p, x = _setup()
    y1, a1 = moe.moe_dropping(p, x, cfg)
    y2, a2 = moe.moe_einsum(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)
    assert abs(float(a1) - float(a2)) < 1e-6


def test_no_drops_at_high_capacity_matches_dense():
    cfg, p, x = _setup(cf=16.0)
    y1, _ = moe.moe_dropping(p, x, cfg)
    y2, _ = moe.moe_dense(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)


def test_capacity_drops_reduce_output_norm():
    """At tiny capacity most assignments drop -> output shrinks toward 0
    but never NaNs (residual passes dropped tokens through)."""
    cfg_hi, p, x = _setup(cf=16.0)
    cfg_lo = cfg_hi.replace(capacity_factor=0.05)
    y_hi, _ = moe.moe_dropping(p, x, cfg_hi)
    y_lo, _ = moe.moe_dropping(p, x, cfg_lo)
    assert np.all(np.isfinite(np.asarray(y_lo)))
    assert float(jnp.linalg.norm(y_lo)) < float(jnp.linalg.norm(y_hi))


def test_top1_routing_llama4():
    cfg = get_config("llama4-scout-17b-a16e", reduced=True).replace(
        compute_dtype="float32", param_dtype="float32", capacity_factor=8.0)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    p = jax.tree.map(lambda t: t[0], params["stack"]["uniform"]["ffn"])
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 8, cfg.d_model))
    y1, _ = moe.moe_dropping(p, x, cfg)
    y2, _ = moe.moe_dense(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)


def test_aux_loss_uniform_router_is_one():
    """A perfectly uniform router gives the Switch aux loss its minimum
    value (= 1 as normalized)."""
    cfg, p, x = _setup()
    E = cfg.num_experts
    gates = jnp.ones((64, E)) / E
    topi = jnp.tile(jnp.arange(cfg.num_experts_per_token)[None], (64, 1))
    # force uniform assignment across experts
    topi = (jnp.arange(64)[:, None] + topi) % E
    aux = moe.aux_load_balance_loss(gates, topi, E)
    k = cfg.num_experts_per_token
    assert abs(float(aux) - k) < 1e-3  # sum f_e * P_e * E == k when uniform
