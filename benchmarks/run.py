"""Benchmark orchestrator: one bench per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

Prints ``name,value,derived`` CSV.  --full uses paper-scale parameters
(slower); the default sizes finish in a few minutes on CPU.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    full = "--full" in sys.argv
    from benchmarks import (bench_discovery, bench_envelope,
                            bench_inference_scaling, bench_roofline,
                            bench_task_overhead, bench_value_server)

    suites = [
        ("task_overhead (Fig 5)", bench_task_overhead.run,
         {} if full else {"T": 60}),
        ("value_server (Fig 6)", bench_value_server.run,
         {} if full else {"T": 40, "sizes": (1 << 10, 1 << 17, 1 << 20,
                                             10 << 20)}),
        ("inference_scaling (Figs 7/8)", bench_inference_scaling.run,
         {} if full else {"T": 30, "workers": (1, 4, 8)}),
        ("envelope (Fig 9)", bench_envelope.run,
         {} if full else {"T_per_worker": 4}),
        ("discovery (Fig 4)", bench_discovery.run,
         {} if full else {"num_molecules": 600, "qc_budget": 48}),
        ("roofline (dry-run)", bench_roofline.run, {}),
    ]
    print("name,value,derived")
    for title, fn, kw in suites:
        t0 = time.perf_counter()
        try:
            rows = fn(**kw)
        except Exception as e:                     # noqa: BLE001
            print(f"{title},ERROR,{e!r}")
            continue
        for name, val, extra in rows:
            if isinstance(val, float):
                print(f"{name},{val:.4f},{extra}")
            else:
                print(f"{name},{val},{extra}")
        print(f"# {title} done in {time.perf_counter()-t0:.1f}s")


if __name__ == "__main__":
    main()
