"""Paper Fig. 5: median per-task lifecycle component times, with and
without the Value Server, for SynApp {T, D=0, I=1MB, O=0, N=8}."""
from __future__ import annotations

from repro.apps.synapp import SynConfig, run_synapp

COMPONENTS = ("serialize_request", "request_queue_transit",
              "serialize_result", "result_queue_transit",
              "deserialize_result", "proxy_put")


def _d0_rows(T: int, N: int):
    """True zero-length tasks with small inputs: measures the dispatch
    floor of the fabric itself (polling loops would show up here).  The
    backend dimension tracks the cross-process transport overhead
    trajectory: "local" is thread workers on in-process queues, "proc"
    is the paper's topology (broker-backed socket queues + worker OS
    processes).  Shared by the full run and the CI quick subset so the
    row names the bench-smoke gate matches on can never drift between
    them."""
    rows = []
    for backend in ("local", "proc"):
        res = run_synapp(SynConfig(T=T, D=0.0, I=1 << 10, O=0, N=N,
                                   use_value_server=False, backend=backend))
        suffix = "" if backend == "local" else f"[{backend}]"
        rows.append((f"d0_per_task_wall{suffix}",
                     res["per_task_wall"] * 1e6, f"n={res['n_results']}"))
        rows.append((f"d0_total_overhead{suffix}",
                     res["total_overhead_median"] * 1e6,
                     f"median lifecycle overhead at D=0, {backend} backend"))
    return rows


def run(T: int = 200, I: int = 1 << 20, N: int = 8, D: float = 0.005):
    """D is near-zero (paper: zero-length tasks) but non-zero so the
    single-CPU consumer thread keeps up and queue *waiting* (a container
    artifact) does not mask the serialization/transfer components."""
    rows = []
    for use_vs in (False, True):
        res = run_synapp(SynConfig(T=T, D=D, I=I, O=0, N=N,
                                   use_value_server=use_vs))
        tag = "vs" if use_vs else "novs"
        for comp in COMPONENTS:
            if comp in res["medians"]:
                rows.append((f"fig5_{tag}_{comp}",
                             res["medians"][comp] * 1e6, ""))
        rows.append((f"fig5_{tag}_total_overhead",
                     res["total_overhead_median"] * 1e6,
                     f"n={res['n_results']}"))
    # the paper's claim: VS reduces serialization+communication for 1MB
    novs = [r for r in rows if r[0] == "fig5_novs_total_overhead"][0][1]
    vs = [r for r in rows if r[0] == "fig5_vs_total_overhead"][0][1]
    rows.append(("fig5_vs_improvement_pct", 100.0 * (novs - vs) / novs,
                 "expect >0 at 1MB"))
    rows.extend(_d0_rows(T, N))
    rows.extend(_direct_rows(T, N))
    rows.extend(_trace_rows(T, N))
    # proc-backend 1MB row alongside the fig5 numbers: what crossing real
    # process boundaries (and the sharded VS) costs at the paper's I=1MB
    for use_vs in (False, True):
        res = run_synapp(SynConfig(T=T, D=D, I=I, O=0, N=N,
                                   use_value_server=use_vs, backend="proc"))
        tag = "vs" if use_vs else "novs"
        rows.append((f"fig5_{tag}_total_overhead[proc]",
                     res["total_overhead_median"] * 1e6,
                     f"n={res['n_results']}"))
    rows.extend(run_checkpoint_bench())
    rows.extend(run_device_array_bench())
    return rows


def _direct_rows(T: int, N: int, reps: int = 3):
    """Cluster D=0 with the Thinker homed away from the pools: this used
    to measure a per-frame relay hop (old bound: <=2x the single-broker
    floor).  With the direct-path data plane there is no hop any more --
    after a one-time ``endpoints`` discovery every submission and result
    dials the topic's home broker directly -- so remote placement should
    cost nothing.  The floor arm is the SAME 2-host fabric with the
    Thinker co-homed with its topic (every data-plane frame at one
    broker): same TCP sockets, same launcher, same process census --
    the only variable is the Thinker's placement, i.e. exactly what the
    direct path changed.  (Comparing against ``d0_per_task_wall[proc]``
    instead would smuggle in the unix-socket-vs-TCP-loopback tax of the
    single-host backend, which no data-plane design can remove.)  The
    ratio row is the CI acceptance gate (``--max-cluster-direct-ratio``,
    bound 1.1x): arms are interleaved and best-of-``reps`` so a load
    burst on a shared CI runner degrades both instead of poisoning
    whichever one it landed on."""
    floor_cfg = SynConfig(T=T, D=0.0, I=1 << 10, O=0, N=N,
                          use_value_server=False, cluster_hosts=2,
                          cluster_thinker_remote=False)
    direct_cfg = SynConfig(T=T, D=0.0, I=1 << 10, O=0, N=N,
                           use_value_server=False, cluster_hosts=2,
                           cluster_thinker_remote=True)
    floor_us = direct_us = None
    n_results = 0
    for _ in range(reps):
        f = run_synapp(floor_cfg)["per_task_wall"] * 1e6
        res = run_synapp(direct_cfg)
        d = res["per_task_wall"] * 1e6
        n_results = res["n_results"]
        floor_us = f if floor_us is None else min(floor_us, f)
        direct_us = d if direct_us is None else min(direct_us, d)
    return [("cluster_d0_direct_per_task_wall", direct_us,
             f"n={n_results}, best of {reps}, remote Thinker; co-homed "
             f"floor={floor_us:.0f}us on the same fabric"),
            ("cluster_d0_direct_ratio", direct_us / floor_us,
             "remote-Thinker wall / co-homed single-broker floor, same "
             f"2-host fabric (interleaved, best of {reps} each); "
             "acceptance <=1.1x")]


def _trace_rows(T: int, N: int, reps: int = 3):
    """What the tracing plane costs when it is ON: the same D=0
    proc-backend dispatch-floor config, one arm untraced, one arm at
    the *default* sampling rate (the shipped knob -- this ratio is the
    CI acceptance gate, ``--max-trace-overhead-ratio``, bound 1.05x),
    and one informational arm at ``trace_sample=1.0`` (every task
    emits its full span set through every hop -- the worst case, kept
    visible so a hot-path regression in the tracer shows up even when
    sampling hides it from the gate).  Arms are interleaved and
    best-of-``reps`` like the cluster ratio.  The obs env is scrubbed
    before the off arm because ``run_synapp`` exports it process-wide
    for the fabric's forked children."""
    import os
    import shutil
    import tempfile

    from repro import observability as obs
    from repro.observability import trace as obs_trace

    base = dict(T=T, D=0.0, I=1 << 10, O=0, N=N,
                use_value_server=False, backend="proc")
    off_us = dflt_us = full_us = None
    n_results = 0
    sink_root = tempfile.mkdtemp(prefix="repro-bench-obs-")

    def scrub():
        os.environ.pop(obs.ENV_DIR, None)
        os.environ.pop(obs.ENV_SAMPLE, None)
        obs_trace._T._pid = -1              # tracer re-reads the env

    try:
        for rep in range(reps):
            scrub()
            off = run_synapp(SynConfig(**base))["per_task_wall"] * 1e6
            scrub()
            res = run_synapp(SynConfig(
                **base, trace_sample=obs.DEFAULT_SAMPLE,
                trace_dir=f"{sink_root}/dflt{rep}"))
            dflt = res["per_task_wall"] * 1e6
            n_results = res["n_results"]
            scrub()
            full = run_synapp(SynConfig(
                **base, trace_sample=1.0,
                trace_dir=f"{sink_root}/full{rep}"))["per_task_wall"] * 1e6
            off_us = off if off_us is None else min(off_us, off)
            dflt_us = dflt if dflt_us is None else min(dflt_us, dflt)
            full_us = full if full_us is None else min(full_us, full)
    finally:
        scrub()
        shutil.rmtree(sink_root, ignore_errors=True)
    return [("d0_traced_per_task_wall[proc]", dflt_us,
             f"n={n_results}, default sampling "
             f"({obs.DEFAULT_SAMPLE:g}), best of {reps}; untraced "
             f"floor={off_us:.0f}us interleaved"),
            ("d0_trace_overhead_ratio", dflt_us / off_us,
             "default-sampling D=0 proc wall / untraced wall "
             f"(interleaved, best of {reps} each); acceptance <=1.05x"),
            ("d0_trace_overhead_ratio[full]", full_us / off_us,
             "trace_sample=1.0 wall / untraced wall -- informational "
             "worst case, not gated")]


def run_device_array_bench(mib: int = 8, reps: int = 5):
    """The zero-copy device-array lane: put/get roundtrip of a multi-MB
    array through a real shard process, typed ndcodec path vs a
    codec-off client (the old pickle path -- the formats self-describe,
    so both clients read the same shard).  The arms are interleaved and
    each takes its best of ``reps`` (after a warmup pass), so load
    drift degrades both equally instead of poisoning one."""
    import time

    import numpy as np

    from repro.core.transport.shards import ShardedValueServer

    try:
        import jax.numpy as jnp
        arr = jnp.arange(mib << 18, dtype=jnp.float32)     # mib MiB
        kind = "jax"
    except Exception:                   # pragma: no cover - jax baked in
        arr = np.arange(mib << 18, dtype=np.float32)
        kind = "np"
    nbytes = mib << 20

    def roundtrip(client):
        t0 = time.perf_counter()
        key = client.put(arr, sync=True)
        out = client.get(key)
        dt = time.perf_counter() - t0
        assert np.asarray(out).nbytes == nbytes
        client.delete(key)
        return dt * 1e3

    vs = ShardedValueServer(1)
    try:
        plain = ShardedValueServer.connect([a for _, a in vs._members],
                                           array_codec=False)
        roundtrip(vs), roundtrip(plain)            # warmup both arms
        t_codec = t_pickle = None
        for _ in range(reps):
            tc, tp = roundtrip(vs), roundtrip(plain)
            t_codec = tc if t_codec is None else min(t_codec, tc)
            t_pickle = tp if t_pickle is None else min(t_pickle, tp)
    finally:
        vs.shutdown()
    note = f"{mib}MiB {kind} array, best of {reps}"
    return [("vs_device_array_roundtrip_ms", t_codec, note),
            ("vs_device_array_roundtrip_pickle_ms", t_pickle, note),
            ("vs_device_array_codec_speedup", t_pickle / t_codec,
             "pickle-path roundtrip / typed-codec roundtrip; expect >1")]


def run_checkpoint_bench(n_envs: int = 500, env_bytes: int = 2048):
    """Cost of the exactly-once machinery's checkpoint path: snapshot +
    restore of a broker holding ``n_envs`` queued envelopes (the price a
    campaign pays per ``--checkpoint-every`` interval)."""
    import time

    from repro.core.transport import Envelope, make_transport
    from repro.utils.timing import now as tnow

    t = make_transport("proc")
    try:
        ch = t.channel("bench", "requests")
        payload = b"\0" * env_bytes
        for i in range(n_envs):
            ch.put(Envelope(tnow(), payload, {"task_id": str(i)}))
        t0 = time.perf_counter()
        snap = t.snapshot()
        t_snap = time.perf_counter() - t0
        t2 = make_transport("proc")
        try:
            t0 = time.perf_counter()
            t2.restore(snap)
            t_restore = time.perf_counter() - t0
        finally:
            t2.close()
    finally:
        t.close()
    note = f"{n_envs}x{env_bytes}B queued, {len(snap)}B snapshot"
    return [("ckpt_snapshot_ms", t_snap * 1e3, note),
            ("ckpt_restore_ms", t_restore * 1e3, note)]


def run_quick(T: int = 100, N: int = 8):
    """The CI smoke subset: the D=0 dispatch-floor rows on both
    backends, the direct-path cluster ratio and the trace-overhead
    ratio (the rows the bench-smoke gates bound -- a ratio of two
    interleaved walls is far less machine-sensitive than any
    absolute-ms floor), and the device-array roundtrip.  The fig5 /
    checkpoint sweeps still need a quiet machine and stay in the
    full run."""
    rows = _d0_rows(T, N)
    rows.extend(_direct_rows(T, N))
    rows.extend(_trace_rows(T, N))
    rows.extend(run_device_array_bench())
    return rows


def main(argv=None) -> int:
    """CLI for the CI bench-smoke job: run (optionally just the quick
    D=0 subset), write the rows as JSON, and fail when the local-backend
    dispatch floor exceeds the acceptance bound -- the first automated
    guard on the perf trajectory."""
    import argparse
    import json

    p = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    p.add_argument("-T", type=int, default=None,
                   help="tasks per config (default: 100 quick, 200 full --"
                        " the full default must track run()'s so bare"
                        " invocations stay comparable across PRs)")
    p.add_argument("--quick", action="store_true",
                   help="only the D=0 rows on both backends")
    p.add_argument("--json", default="", metavar="PATH",
                   help="also write rows as JSON (name -> {value_us, note})")
    p.add_argument("--max-d0-local-ms", type=float, default=0.0,
                   metavar="MS",
                   help="fail (exit 1) if d0_per_task_wall exceeds this")
    p.add_argument("--max-cluster-direct-ratio", type=float, default=0.0,
                   metavar="X",
                   help="fail (exit 1) if cluster_d0_direct_ratio (the "
                        "direct-path cluster wall over the single-broker "
                        "proc floor, same run) exceeds this")
    p.add_argument("--max-trace-overhead-ratio", type=float, default=0.0,
                   metavar="X",
                   help="fail (exit 1) if d0_trace_overhead_ratio (the "
                        "fully-traced D=0 proc wall over the untraced "
                        "wall, interleaved) exceeds this")
    args = p.parse_args(argv)
    if args.quick:
        rows = run_quick(**({} if args.T is None else {"T": args.T}))
    else:
        rows = run(**({} if args.T is None else {"T": args.T}))
    for name, val, extra in rows:
        print(f"{name},{val:.1f},{extra}")
    if args.json:
        # neutral "value": most rows are microseconds, but full runs
        # include e.g. fig5_vs_improvement_pct -- a unit-bearing key
        # would mislabel those for artifact consumers
        with open(args.json, "w") as f:
            json.dump({name: {"value": val, "note": extra}
                       for name, val, extra in rows}, f, indent=2)
    if args.max_d0_local_ms:
        d0_us = next(v for n, v, _ in rows if n == "d0_per_task_wall")
        bound_us = args.max_d0_local_ms * 1e3
        if d0_us > bound_us:
            print(f"FAIL: d0_per_task_wall {d0_us:.0f}us exceeds the "
                  f"{args.max_d0_local_ms:.1f}ms acceptance bound")
            return 1
        print(f"OK: d0_per_task_wall {d0_us:.0f}us within "
              f"{args.max_d0_local_ms:.1f}ms")
    if args.max_cluster_direct_ratio:
        ratio = next(v for n, v, _ in rows
                     if n == "cluster_d0_direct_ratio")
        if ratio > args.max_cluster_direct_ratio:
            print(f"FAIL: cluster_d0_direct_ratio {ratio:.2f}x exceeds "
                  f"the {args.max_cluster_direct_ratio:.2f}x acceptance "
                  "bound (direct path should sit on the single-broker "
                  "floor)")
            return 1
        print(f"OK: cluster_d0_direct_ratio {ratio:.2f}x within "
              f"{args.max_cluster_direct_ratio:.2f}x")
    if args.max_trace_overhead_ratio:
        ratio = next(v for n, v, _ in rows
                     if n == "d0_trace_overhead_ratio")
        if ratio > args.max_trace_overhead_ratio:
            print(f"FAIL: d0_trace_overhead_ratio {ratio:.2f}x exceeds "
                  f"the {args.max_trace_overhead_ratio:.2f}x acceptance "
                  "bound (full-sampling tracing should stay in the "
                  "dispatch-floor noise)")
            return 1
        print(f"OK: d0_trace_overhead_ratio {ratio:.2f}x within "
              f"{args.max_trace_overhead_ratio:.2f}x")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
