"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads benchmarks/results/dryrun/*.json (written by repro.launch.dryrun) and
emits, per (arch x shape) single-pod cell: the three roofline terms, the
dominant bottleneck, MODEL_FLOPS / HLO_FLOPs, and per-device memory.
"""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def load_cells(mesh: str = "single"):
    cells = []
    for f in sorted(glob.glob(os.path.join(RESULTS, f"*_{mesh}.json"))):
        rec = json.load(open(f))
        if rec.get("status") == "ok":
            cells.append(rec)
    return cells


def run(mesh: str = "single"):
    rows = []
    for rec in load_cells(mesh):
        r = rec["roofline"]
        tag = f"{rec['arch']}|{rec['shape']}"
        rows.append((f"roofline_{tag}_compute_s", r["compute_s"], ""))
        rows.append((f"roofline_{tag}_memory_s", r["memory_analytic_s"],
                     f"xla_unfused={r['memory_s']:.4f}"))
        rows.append((f"roofline_{tag}_collective_s", r["collective_s"], ""))
        rows.append((f"roofline_{tag}_dominant", 0.0,
                     r["dominant_analytic"]))
        rows.append((f"roofline_{tag}_useful_flop_frac",
                     rec["useful_flop_frac"], "MODEL_FLOPS/HLO_FLOPS"))
    return rows


def markdown_table(mesh: str = "single") -> str:
    lines = [
        "| arch | shape | compute (s) | memory (s) | collective (s) | "
        "dominant | useful | roofline frac | bytes/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in load_cells(mesh):
        r = rec["roofline"]
        mem = rec.get("memory_analysis") or {}
        arg = mem.get("argument_size_in_bytes", 0)
        bound = max(r["compute_s"], r["memory_analytic_s"],
                    r["collective_s"])
        frac = r["compute_s"] / bound if bound else 0.0
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_analytic_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['dominant_analytic']} | {rec['useful_flop_frac']:.2f} | "
            f"{frac:.2f} | {arg/1e9:.2f} GB |")
    return "\n".join(lines)


if __name__ == "__main__":
    import sys
    if "--markdown" in sys.argv:
        print(markdown_table())
    else:
        for name, val, extra in run():
            print(f"{name},{val},{extra}")
