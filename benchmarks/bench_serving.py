"""Inference shard throughput: batched continuous serving vs. unbatched
one-Engine-call-per-task dispatch, through the same broker fabric.

Both arms fork a real shard process (``repro.serving.shard``) against a
proc-backend broker and drive N queued requests through it with the
``InferenceClient``; the only difference is ``ServeSpec.max_batch`` --
32 (pad-bounded micro-batches + continuous decode) vs. 1 (every request
is its own prefill + decode loop, the pre-shard dispatch pattern).  The
reported ``inference_tasks_per_sec`` therefore isolates exactly what the
subsystem claims: micro-batching amortizes the per-call engine overhead
(dispatch, launch, weight traffic) across the batch, on top of an
identical exactly-once transport.

The engine is the reduced reference model, built *inside* the shard
child (this parent process never imports jax).  A warmup wave per arm
pays the jit compiles before the clock starts, so the rows report warm
steady-state -- the same honesty rule as ``Engine.throughput()``.
"""
from __future__ import annotations

import time

PROMPT_BUCKETS = (16,)
MAX_NEW = 8


def _spec(max_batch: int):
    from repro.serving.shard import ServeSpec, default_engine_factory
    return ServeSpec(engine_factory=default_engine_factory(max_new=64),
                     max_batch=max_batch, prompt_buckets=PROMPT_BUCKETS,
                     max_batch_delay_ms=5.0, max_new_cap=64,
                     default_max_new=MAX_NEW)


def _prompts(n: int):
    # ragged lengths within one bucket: realistic padding, one prompt
    # executable shape per batch bucket
    return [[(i % 251) + 1] * (8 + i % 9) for i in range(n)]


def _run_arm(max_batch: int, n: int, timeout: float):
    """One shard, one client, N queued requests; returns tasks/sec."""
    from repro.core.queues import ColmenaQueues
    from repro.serving.shard import (InferenceClient, send_shard_stop,
                                     start_inference_shard)
    spec = _spec(max_batch)
    q = ColmenaQueues([], backend="proc", lease_timeout=60.0,
                      serve_spec=spec)
    proc = None
    try:
        proc = start_inference_shard(q.transport.address, spec,
                                     lease_timeout=60.0,
                                     identity=f"infer@bench:b{max_batch}")
        client = InferenceClient(q)
        # warmup: pays engine build + jit compile for every batch bucket
        # this arm can see (the prompt bucket and cache reserve are
        # constant here, so the executable key varies only by batch).
        # Ascending pow2 waves: even when arrival raggedness splits a
        # wave into partial batches, every piece's bucket is a size an
        # earlier wave already compiled
        b = 1
        while True:
            client.infer(_prompts(b), max_new=MAX_NEW, timeout=timeout)
            if b >= max_batch:
                break
            b = min(b * 2, max_batch)
        t0 = time.perf_counter()
        res = client.infer(_prompts(n), max_new=MAX_NEW, timeout=timeout)
        wall = time.perf_counter() - t0
        bad = [r for r in res if not r.success]
        assert not bad, bad[0].error
        return n / wall, wall
    finally:
        try:
            send_shard_stop(q.transport, spec.topic)
        except (ConnectionError, OSError):
            pass
        if proc is not None:
            proc.join(timeout=10)
            if proc.is_alive():
                proc.terminate()
        q.shutdown()


def run(n: int = 1000, timeout: float = 1200.0):
    """The acceptance configuration: N=1,000 queued requests, batched
    (max_batch=32) vs. unbatched (max_batch=1), expect >= 3x."""
    rows = []
    batched, wall_b = _run_arm(32, n, timeout=timeout)
    rows.append(("inference_tasks_per_sec[batched]", batched,
                 f"N={n}, max_batch=32, wall {wall_b:.1f}s"))
    unbatched, wall_u = _run_arm(1, n, timeout=timeout)
    rows.append(("inference_tasks_per_sec[unbatched]", unbatched,
                 f"N={n}, max_batch=1, wall {wall_u:.1f}s"))
    rows.append(("inference_batching_speedup", batched / unbatched,
                 "batched / unbatched, expect >= 3x"))
    return rows


def run_quick(n: int = 128, timeout: float = 600.0):
    """CI smoke subset: same two arms and row names at a size a shared
    runner finishes in minutes.  The speedup gate still applies -- the
    amortization claim does not need N=1,000 to show up."""
    return run(n=n, timeout=timeout)


def main(argv=None) -> int:
    import argparse
    import json

    p = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    p.add_argument("-N", type=int, default=None,
                   help="queued requests per arm (default: 128 quick,"
                        " 1000 full)")
    p.add_argument("--quick", action="store_true",
                   help="CI smoke size (N=128)")
    p.add_argument("--json", default="", metavar="PATH",
                   help="also write rows as JSON (name -> {value, note})")
    p.add_argument("--min-speedup", type=float, default=0.0, metavar="X",
                   help="fail (exit 1) if batched/unbatched < X")
    args = p.parse_args(argv)
    fn = run_quick if args.quick else run
    rows = fn(**({} if args.N is None else {"n": args.N}))
    for name, val, extra in rows:
        print(f"{name},{val:.2f},{extra}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({name: {"value": val, "note": extra}
                       for name, val, extra in rows}, f, indent=2)
    if args.min_speedup:
        speedup = next(v for name, v, _ in rows
                       if name == "inference_batching_speedup")
        if speedup < args.min_speedup:
            print(f"FAIL: batching speedup {speedup:.2f}x below the "
                  f"{args.min_speedup:.1f}x acceptance bound")
            return 1
        print(f"OK: batching speedup {speedup:.2f}x >= "
              f"{args.min_speedup:.1f}x")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
