"""Paper Fig. 9: Colmena performance envelopes -- average worker
utilization as a function of task duration D, payload size s (I = O = s)
and worker count N.  The paper's envelope: 100s/1MB/512-worker tasks reach
~90%; shorter tasks need smaller payloads or less parallelism."""
from __future__ import annotations

from repro.apps.synapp import SynConfig, run_synapp


def run(T_per_worker: int = 6,
        durations=(0.005, 0.02, 0.1),
        sizes=(1 << 10, 1 << 18, 1 << 20),
        workers=(2, 8)):
    rows = []
    for N in workers:
        for D in durations:
            for s in sizes:
                res = run_synapp(SynConfig(
                    T=T_per_worker * N, D=D, I=s, O=s, N=N,
                    use_value_server=True))
                rows.append((
                    f"fig9_util_N={N}_D={D}_s={s}",
                    100.0 * res["utilization"],
                    f"makespan_ms={res['makespan']*1e3:.0f}"))
    return rows


if __name__ == "__main__":
    for name, val, extra in run():
        print(f"{name},{val:.1f},{extra}")
