"""Paper Figs. 7/8: ML-inference task scaling.

Fig. 7: molecule evaluations/second vs number of (thread) workers.
Fig. 8: result-transfer time (worker -> thinker) with vs without the Value
Server as worker count grows -- the paper's point is that the VS keeps
transfer time flat because large results stop flowing through the queue
path.

Simulation caveat (documented in EXPERIMENTS.md): workers are threads on
one CPU, so Fig. 7 cannot show real multi-node speedup; the *relative*
VS-vs-no-VS transfer behaviour (Fig. 8) is the reproducible claim.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.apps.synapp import SynConfig, run_synapp
from repro.configs import mpnn_surrogate
from repro.data import molecules


def inference_rate(n_molecules: int = 512) -> float:
    """Molecules/second through the (jitted) MPNN ensemble, CPU."""
    from repro.apps.electrolyte import Surrogate
    cfg = mpnn_surrogate.reduced()
    s = Surrogate(cfg)
    space = molecules.MoleculeSpace(num_molecules=n_molecules)
    feats = jax.tree.map(jax.numpy.asarray,
                         molecules.featurize(space, range(n_molecules)))
    s.predict(feats)                       # compile
    t0 = time.perf_counter()
    s.predict(feats)
    dt = time.perf_counter() - t0
    return n_molecules / dt


def run(T: int = 60, result_mb: float = 1.0, workers=(1, 2, 4, 8)):
    rows = [("fig7_inference_rate_mol_per_s", inference_rate(), "jit, CPU")]
    O = int(result_mb * (1 << 20))
    for N in workers:
        for use_vs in (False, True):
            res = run_synapp(SynConfig(T=T, D=0.01, I=1 << 10, O=O, N=N,
                                       use_value_server=use_vs))
            transfer = res["medians"].get("result_queue_transit", 0.0) + \
                res["medians"].get("serialize_result", 0.0)
            tag = "vs" if use_vs else "novs"
            rows.append((f"fig8_result_transfer_us_{tag}_N={N}",
                         transfer * 1e6, ""))
    return rows


if __name__ == "__main__":
    for name, val, extra in run():
        print(f"{name},{val:.1f},{extra}")
