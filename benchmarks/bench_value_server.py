"""Paper Fig. 6: % reduction in SynApp communication overhead with the
Value Server vs without, as a function of input size I.  The paper finds
VS helps above ~0.1 MB and hurts below ~10 KB.

Also benchmarks the store itself along the backend dimension (in-process
vs sharded-over-sockets) and the spill tier (memory hit vs disk fault-in
latency), so the cross-process overhead trajectory is tracked from the
transport PR onward."""
from __future__ import annotations

import os
import tempfile

import numpy as np

from repro.apps.synapp import SynConfig, run_synapp
from repro.core import ShardedValueServer, ValueServer
from repro.utils.timing import now


def _median_us(samples):
    return float(np.median(samples)) * 1e6


def store_rows(size: int = 1 << 20, reps: int = 20):
    """put/get latency per backend + spill-tier hit vs miss."""
    rows = []
    payload = os.urandom(size)

    # backend dimension: in-process dict vs shard process over a socket
    for backend, vs in (("local", ValueServer()),
                        ("proc", ShardedValueServer(2))):
        puts, gets = [], []
        for _ in range(reps):
            t0 = now(); key = vs.put(payload); puts.append(now() - t0)
            t0 = now(); vs.get(key); gets.append(now() - t0)
            vs.delete(key)
        rows.append((f"vs_put_us[{backend}]", _median_us(puts),
                     f"I={size}"))
        rows.append((f"vs_get_us[{backend}]", _median_us(gets),
                     f"I={size}"))
        if hasattr(vs, "shutdown"):
            vs.shutdown()

    # spill tier: hold two entries against a one-entry budget so each get
    # of the cold key is a disk fault-in (miss) that spills the other;
    # re-getting the now-hot key is a memory hit
    with tempfile.TemporaryDirectory() as spill_dir:
        vs = ValueServer(capacity_bytes=int(size * 1.5), spill_dir=spill_dir)
        ka, kb = vs.put(payload), vs.put(os.urandom(size))
        hits, misses = [], []
        cold, hot = ka, kb
        for _ in range(reps):
            t0 = now(); vs.get(cold); misses.append(now() - t0)
            t0 = now(); vs.get(cold); hits.append(now() - t0)
            cold, hot = hot, cold
        rows.append(("vs_get_hit_us[spill]", _median_us(hits),
                     "memory-tier hit"))
        rows.append(("vs_get_miss_us[spill]", _median_us(misses),
                     "disk fault-in"))
    return rows


def run(T: int = 100, N: int = 8, sizes=(1 << 10, 1 << 14, 1 << 17,
                                         1 << 20, 10 << 20)):
    rows = []
    for I in sizes:
        o_no = run_synapp(SynConfig(T=T, D=0.0, I=I, O=0, N=N,
                                    use_value_server=False))
        o_vs = run_synapp(SynConfig(T=T, D=0.0, I=I, O=0, N=N,
                                    use_value_server=True,
                                    proxy_threshold=1 << 13))
        no, vs = (o_no["total_overhead_median"],
                  o_vs["total_overhead_median"])
        pct = 100.0 * (no - vs) / max(no, 1e-12)
        rows.append((f"fig6_reduction_pct_I={I}", pct,
                     f"novs_us={no*1e6:.0f};vs_us={vs*1e6:.0f}"))
    rows.extend(store_rows())
    return rows


if __name__ == "__main__":
    for name, val, extra in run():
        print(f"{name},{val:.1f},{extra}")
