"""Paper Fig. 6: % reduction in SynApp communication overhead with the
Value Server vs without, as a function of input size I.  The paper finds
VS helps above ~0.1 MB and hurts below ~10 KB."""
from __future__ import annotations

from repro.apps.synapp import SynConfig, run_synapp


def run(T: int = 100, N: int = 8, sizes=(1 << 10, 1 << 14, 1 << 17,
                                         1 << 20, 10 << 20)):
    rows = []
    for I in sizes:
        o_no = run_synapp(SynConfig(T=T, D=0.0, I=I, O=0, N=N,
                                    use_value_server=False))
        o_vs = run_synapp(SynConfig(T=T, D=0.0, I=I, O=0, N=N,
                                    use_value_server=True,
                                    proxy_threshold=1 << 13))
        no, vs = (o_no["total_overhead_median"],
                  o_vs["total_overhead_median"])
        pct = 100.0 * (no - vs) / max(no, 1e-12)
        rows.append((f"fig6_reduction_pct_I={I}", pct,
                     f"novs_us={no*1e6:.0f};vs_us={vs*1e6:.0f}"))
    return rows


if __name__ == "__main__":
    for name, val, extra in run():
        print(f"{name},{val:.1f},{extra}")
