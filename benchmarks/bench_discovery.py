"""Paper Fig. 4 + §IV-C2: discovery rate of high-IP molecules under three
steering policies (random / no-retrain / update-n), on the synthetic
oracle.  The reproducible claims:

  1. ML-steered >> random (the paper: ~100x more high-IP molecules;
     success 0.5% random vs 64%/78% steered),
  2. update-n >= no-retrain (retraining helps),
  3. the ML models improve with campaign data (MAE trend).
"""
from __future__ import annotations

from repro.apps.electrolyte import AppConfig, run_campaign


def run(num_molecules: int = 1200, qc_budget: int = 60,
        initial_train: int = 48, n_retrain: int = 12, seed: int = 0):
    kw = dict(num_molecules=num_molecules, qc_budget=qc_budget,
              initial_train=initial_train, n_retrain=n_retrain, seed=seed)
    rows = []
    outs = {}
    for policy in ("random", "no-retrain", "update-n"):
        out = run_campaign(AppConfig(policy=policy, **kw))
        outs[policy] = out
        rows.append((f"fig4_{policy}_n_high", out["n_high"],
                     f"of {out['n_evaluated']} evaluated"))
        rows.append((f"fig4_{policy}_success_pct",
                     100.0 * out["success_rate"],
                     f"best={out['best']:.2f}V"))
        rows.append((f"fig4_{policy}_mean_last_quarter",
                     out["mean_last_quarter"], "late-run mean IP (V)"))
    rand = max(outs["random"]["success_rate"], 1e-4)
    rows.append(("fig4_steered_vs_random_x",
                 outs["update-n"]["success_rate"] / rand,
                 "paper: ~100x"))
    rows.append(("fig4_retrain_mae_delta",
                 outs["update-n"]["initial_mae"]
                 - outs["update-n"]["final_mae"],
                 "positive = model improved during campaign"))
    return rows


if __name__ == "__main__":
    for name, val, extra in run():
        print(f"{name},{val},{extra}")
