"""Paper Fig. 4 + §IV-C2: discovery rate of high-IP molecules under three
steering policies (random / no-retrain / update-n), on the synthetic
oracle.  The reproducible claims:

  1. ML-steered >> random (the paper: ~100x more high-IP molecules;
     success 0.5% random vs 64%/78% steered),
  2. update-n >= no-retrain (retraining helps),
  3. the ML models improve with campaign data (MAE trend).

Plus the streaming-steering claim: when tasks publish partial results
mid-run, a Thinker that preempts losers on their *first* partial
(broker-side ``cancel``) finishes the same candidate sweep faster than
one that lets every task run to completion.  Both arms run the real
synapp fabric (local queues, thread task server, stream lane, fused
cancel claim); with keep fraction p and S slices per task the ideal
speedup is 1/(p + (1-p)/S) -- the ``discovery_preemption_speedup`` row
measures how much of it survives real dispatch overheads.
"""
from __future__ import annotations

from repro.apps.electrolyte import AppConfig, run_campaign
from repro.apps.synapp import SynConfig, run_synapp

# preemption-arm shape: C candidates on W workers, each S slices of DT
# seconds; the culling Thinker keeps ~KEEP of them (pseudo-scores are
# uniform, so cull_losers = 1 - KEEP).  Ideal speedup here:
# 1 / (0.25 + 0.75/6) = 2.67x
CANDIDATES = 16
WORKERS = 4
SLICES = 6
SLICE_DT = 0.05
KEEP = 0.25


def _discovery_arm(cull: bool, seed: int = 0):
    cfg = SynConfig(T=CANDIDATES, D=SLICES * SLICE_DT, I=1024, O=0,
                    N=WORKERS, use_value_server=False, backend="local",
                    seed=seed,
                    cull_losers=(1.0 - KEEP) if cull else 0.0,
                    cull_steps=SLICES)
    return run_synapp(cfg)


def preemption_rows(seed: int = 0):
    """The streaming-steering arms: identical candidate sweep, with and
    without first-partial preemption."""
    base = _discovery_arm(cull=False, seed=seed)
    pre = _discovery_arm(cull=True, seed=seed)
    speedup = base["makespan"] / max(pre["makespan"], 1e-9)
    ideal = 1.0 / (KEEP + (1.0 - KEEP) / SLICES)
    return [
        ("discovery_run_to_completion_s", base["makespan"],
         f"C={CANDIDATES}, W={WORKERS}, S={SLICES}x{SLICE_DT}s, no cull"),
        ("discovery_preemption_s", pre["makespan"],
         f"culled {pre['culled']} of {CANDIDATES} on first partial"),
        ("discovery_preemption_speedup", speedup,
         f"run-to-completion / preemption, ideal {ideal:.2f}x"),
    ]


def run(num_molecules: int = 1200, qc_budget: int = 60,
        initial_train: int = 48, n_retrain: int = 12, seed: int = 0):
    kw = dict(num_molecules=num_molecules, qc_budget=qc_budget,
              initial_train=initial_train, n_retrain=n_retrain, seed=seed)
    rows = []
    outs = {}
    for policy in ("random", "no-retrain", "update-n"):
        out = run_campaign(AppConfig(policy=policy, **kw))
        outs[policy] = out
        rows.append((f"fig4_{policy}_n_high", out["n_high"],
                     f"of {out['n_evaluated']} evaluated"))
        rows.append((f"fig4_{policy}_success_pct",
                     100.0 * out["success_rate"],
                     f"best={out['best']:.2f}V"))
        rows.append((f"fig4_{policy}_mean_last_quarter",
                     out["mean_last_quarter"], "late-run mean IP (V)"))
    rand = max(outs["random"]["success_rate"], 1e-4)
    rows.append(("fig4_steered_vs_random_x",
                 outs["update-n"]["success_rate"] / rand,
                 "paper: ~100x"))
    rows.append(("fig4_retrain_mae_delta",
                 outs["update-n"]["initial_mae"]
                 - outs["update-n"]["final_mae"],
                 "positive = model improved during campaign"))
    rows.extend(preemption_rows(seed=seed))
    return rows


def run_quick(seed: int = 0):
    """CI smoke subset: just the preemption arms (the fig4 campaigns
    train real models and take minutes; the streaming-steering claim
    needs only the two synapp arms, seconds each)."""
    return preemption_rows(seed=seed)


def main(argv=None) -> int:
    import argparse
    import json

    p = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    p.add_argument("--quick", action="store_true",
                   help="CI smoke: preemption arms only, no fig4 "
                        "campaigns")
    p.add_argument("--json", default="", metavar="PATH",
                   help="also write rows as JSON (name -> {value, note})")
    p.add_argument("--min-speedup", type=float, default=0.0, metavar="X",
                   help="fail (exit 1) if discovery_preemption_speedup "
                        "< X")
    args = p.parse_args(argv)
    rows = run_quick() if args.quick else run()
    for name, val, extra in rows:
        print(f"{name},{val},{extra}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({name: {"value": val, "note": extra}
                       for name, val, extra in rows}, f, indent=2)
    if args.min_speedup:
        speedup = next(v for name, v, _ in rows
                       if name == "discovery_preemption_speedup")
        if speedup < args.min_speedup:
            print(f"FAIL: preemption speedup {speedup:.2f}x below the "
                  f"{args.min_speedup:.1f}x acceptance bound")
            return 1
        print(f"OK: preemption speedup {speedup:.2f}x >= "
              f"{args.min_speedup:.1f}x")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
