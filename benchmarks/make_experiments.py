"""Assemble the data-driven sections of EXPERIMENTS.md from the dry-run and
perf-iteration JSON artifacts.

    PYTHONPATH=src python -m benchmarks.make_experiments > /tmp/tables.md
"""
from __future__ import annotations

import glob
import json
import os

HERE = os.path.dirname(__file__)
DRY = os.path.join(HERE, "results", "dryrun")
PERF = os.path.join(HERE, "results", "perf")


def _load(pattern, where=DRY):
    out = []
    for f in sorted(glob.glob(os.path.join(where, pattern))):
        try:
            out.append((os.path.basename(f), json.load(open(f))))
        except Exception:
            pass
    return out


def dryrun_table():
    print("### Dry-run matrix (compile status, per-device memory)\n")
    print("| arch | shape | mesh | status | args GB/dev | peak GB/dev | "
          "compile s |")
    print("|---|---|---|---|---|---|---|")
    for name, r in _load("*.json"):
        mesh = r.get("mesh", "?")
        st = r.get("status")
        if st == "ok":
            mem = r.get("memory_analysis") or {}
            arg = mem.get("argument_size_in_bytes", 0) / 2**30
            peak = mem.get("peak_memory_in_bytes", 0) / 2**30
            print(f"| {r['arch']} | {r['shape']} | {mesh} | ok | "
                  f"{arg:.2f} | {peak:.2f} | {r['t_compile_s']:.0f} |")
        elif st == "skip":
            print(f"| {r['arch']} | {r['shape']} | {mesh} | SKIP | - | - | "
                  f"- |")
        else:
            print(f"| {r['arch']} | {r['shape']} | {mesh} | **FAIL** | - | "
                  f"- | - |")
    print()


def _next_lever(r) -> str:
    """One sentence: what would move this cell's dominant term down."""
    dom = r["roofline"]["dominant_analytic"]
    arch, shape = r["arch"], r["shape"]
    moe = "kimi" in arch or "llama4" in arch
    ssm = arch.startswith(("rwkv", "zamba"))
    if dom == "collective":
        if moe:
            return ("shard_map EP all_to_all dispatch (done in §Perf: "
                    "4.6x) then overlap FSDP gathers with the layer scan")
        if shape.startswith("train"):
            return ("drop TP for this model size: dp_only turns per-layer "
                    "ARs into one gradient AR (done in §Perf: 7-13x)")
        return "batch collectives / overlap with compute"
    if dom == "memory":
        if shape.startswith(("decode", "long")):
            if ssm:
                return ("state already O(1); raise batch to amortize the "
                        "parameter read per token")
            return ("int8 KV cache + larger decode batch amortize the "
                    "cache/param read per token")
        return "fuse elementwise chains; raise arithmetic intensity"
    if "useful" in r and r["useful_flop_frac"] < 0.5:
        return ("recover wasted FLOPs: replicated attention / remat "
                "recompute (see §Perf remat=policy, dp_only)")
    return "near compute roofline; gains only from kernel-level fusion"


def roofline_table():
    print("### Roofline (single-pod 16x16 = 256 chips, TPU v5e targets)\n")
    print("| arch | shape | compute s | memory s (analytic) | memory s "
          "(XLA unfused) | collective s | dominant | useful "
          "(6N·D/HLO) | bound s | what moves the dominant term down |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for name, r in _load("*_single.json"):
        if r.get("status") != "ok":
            continue
        ro = r["roofline"]
        print(f"| {r['arch']} | {r['shape']} | {ro['compute_s']:.4f} | "
              f"{ro['memory_analytic_s']:.4f} | {ro['memory_s']:.3f} | "
              f"{ro['collective_s']:.4f} | {ro['dominant_analytic']} | "
              f"{r['useful_flop_frac']:.2f} | "
              f"{ro['step_lower_bound_analytic_s']:.4f} | "
              f"{_next_lever(r)} |")
    print()


def collective_breakdown(arch, shape):
    recs = _load(f"{arch}_{shape}_single.json")
    if not recs:
        return
    r = recs[0][1]
    det = r.get("collective_probe_detail") or {}
    p1 = det.get("probe1", {})
    print(f"**{arch} x {shape}** per-layer collectives (1-layer probe): ",
          end="")
    parts = []
    for op, d in p1.items():
        parts.append(f"{op}: {d['count']}x, {d['wire_bytes']/2**20:.0f} "
                     f"MiB wire")
    print("; ".join(parts))


def perf_table():
    print("### Perf iterations (hillclimbed cells)\n")
    print("| cell | variant | compute s | memory s | collective s | "
          "dominant | bound s | Δ bound |")
    print("|---|---|---|---|---|---|---|---|")
    cells = {}
    # baselines from the dry-run dir
    for name, r in _load("*_single.json"):
        if r.get("status") == "ok":
            cells[(r["arch"], r["shape"], "baseline")] = r
    for name, r in _load("*_single_*.json", PERF):
        if r.get("status") == "ok":
            tag = name.split("_single_")[1].replace(".json", "")
            cells[(r["arch"], r["shape"], tag)] = r

    seen_cells = sorted({(a, s) for a, s, _ in cells})
    for arch, shape in seen_cells:
        variants = sorted([t for a, s, t in cells if (a, s) == (arch, shape)],
                          key=lambda t: (t != "baseline", t))
        if len(variants) < 2:
            continue
        base = cells[(arch, shape, "baseline")]["roofline"]
        base_bound = base["step_lower_bound_analytic_s"]
        for tag in variants:
            ro = cells[(arch, shape, tag)]["roofline"]
            bound = ro["step_lower_bound_analytic_s"]
            delta = 100.0 * (base_bound - bound) / base_bound
            print(f"| {arch} x {shape} | {tag} | {ro['compute_s']:.4f} | "
                  f"{ro['memory_analytic_s']:.4f} | "
                  f"{ro['collective_s']:.4f} | {ro['dominant_analytic']} | "
                  f"{bound:.4f} | {delta:+.0f}% |")
    print()


if __name__ == "__main__":
    dryrun_table()
    roofline_table()
    perf_table()
    for cell in (("kimi-k2-1t-a32b", "train_4k"),
                 ("internlm2-1.8b", "train_4k"),
                 ("gemma2-2b", "train_4k")):
        collective_breakdown(*cell)
